//! The one-experiment API: `Config → Experiment` resolved in exactly one
//! place.
//!
//! Every entry point — the CLI (`train` / `sweep` / `info` / `solve-ref`),
//! the sweep runtime, the figure/table benches, and the examples — used to
//! re-implement config resolution by hand (problem construction, graph +
//! mixing operator, auto-η, compressor, prox, reference solve). This
//! module is the single pipeline:
//!
//! ```text
//! Config (key = value file / --key overrides)
//!    │  Experiment::from_config            — the ONE resolution pipeline
//!    ▼
//! Experiment {
//!    problem: Arc<dyn Problem>   ← problem registry (logreg |
//!                                   least-squares | lasso)
//!    graph → mixing: MixingOp    ← topology × rule, dense|CSR auto
//!    hyper: Hyper                ← auto-η = 1/(2L) resolved HERE
//!    x0, compressor, prox, oracle, cached reference x*
//! }
//!    │
//!    ├── experiment.algorithm()   → Box<dyn Algorithm>   (registry +
//!    │                              typed builders, see [`registry`])
//!    ├── experiment.run(&RunSpec)             → matrix engine
//!    └── experiment.run_coordinator(&RunSpec) → node threads + wire frames
//! ```
//!
//! Both backends speak the one run vocabulary of [`crate::runner`]
//! (composable stop criteria, streaming probes, unified `RunResult`).
//!
//! Adding a scenario (a new problem family, algorithm, topology, or
//! compressor) means registering it once here — every sweep axis, bench,
//! and CLI flag picks it up automatically.

pub mod registry;

pub use registry::{build_problem, ALGORITHM_NAMES};

use crate::algorithm::{solve_reference, Algorithm, Hyper};
use crate::compress::Compressor;
use crate::config::{Config, ConfigError};
use crate::coordinator::{self, CoordConfig, Straggler, WireCodec};
use crate::graph::{Graph, MixingOp};
use crate::linalg::Mat;
use crate::oracle::OracleKind;
use crate::problem::{Problem, ProblemKind};
use crate::prox::Prox;
use crate::coordinator::node::run_node;
use crate::coordinator::{NodeConfig, WeightRow};
use crate::runner::{self, Probe, RunResult, RunSpec};
use crate::sim;
use crate::transport::{self, socket, Hello, Transport};
use std::sync::{Arc, OnceLock};
use std::time::Duration;

/// Reference-solution budget shared by every resolved experiment — the
/// figure benches' historical 80k-iteration FISTA budget, so even the most
/// ill-conditioned grid cells converge their x* well below the 1e-9
/// measurement targets (FISTA early-stops at the tolerance, so
/// well-conditioned problems pay far less).
pub const REF_MAX_ITER: usize = 80_000;
pub const REF_TOL: f64 = 1e-12;

/// A fully resolved experiment: everything a backend needs, constructed
/// from a [`Config`] by [`Experiment::from_config`] and nowhere else.
///
/// Fields are public so tests and benches can substitute single components
/// (e.g. a dense vs CSR mixing operator via [`Experiment::with_mixing`])
/// while keeping the rest of the resolution identical.
#[derive(Clone)]
pub struct Experiment {
    /// The source configuration (validated: every factory below resolves).
    pub config: Config,
    /// The config-declared problem family (callers injecting a custom
    /// problem via [`ExperimentBuilder::with_problem`] may ignore it).
    pub kind: ProblemKind,
    pub problem: Arc<dyn Problem>,
    pub graph: Graph,
    pub mixing: MixingOp,
    /// Hyperparameters with η resolved (config 0 ⇒ auto 1/(2L)).
    pub hyper: Hyper,
    /// Common start iterate X⁰ = 0 (n × p).
    pub x0: Mat,
    /// Cached high-precision reference x* (λ₁-regularized FISTA).
    x_star: OnceLock<Arc<Vec<f64>>>,
}

impl Experiment {
    /// The single `Config → Experiment` resolution pipeline. Validates
    /// every factory once, so the accessors below are infallible.
    pub fn from_config(cfg: &Config) -> Result<Experiment, ConfigError> {
        let kind = cfg.problem_kind()?;
        let problem = registry::build_problem(cfg)?;
        Experiment::assemble(cfg, kind, problem)
    }

    /// [`Experiment::from_config`] with a caller-built problem instead of
    /// the registry's synthetic one (custom data, wrapped backends).
    /// `config.nodes` must match the problem's node count.
    pub fn from_config_with_problem(
        cfg: &Config,
        problem: Arc<dyn Problem>,
    ) -> Result<Experiment, ConfigError> {
        let kind = cfg.problem_kind()?;
        Experiment::assemble(cfg, kind, problem)
    }

    /// Start a builder over the default configuration.
    pub fn builder() -> ExperimentBuilder {
        ExperimentBuilder::new()
    }

    fn assemble(
        cfg: &Config,
        kind: ProblemKind,
        problem: Arc<dyn Problem>,
    ) -> Result<Experiment, ConfigError> {
        if problem.num_nodes() != cfg.nodes {
            return Err(ConfigError(format!(
                "problem has {} nodes but the config says nodes = {}",
                problem.num_nodes(),
                cfg.nodes
            )));
        }
        // one shared factory checklist (also what validate_config runs),
        // so the accessors below can unwrap safely
        validate_runtime_factories(cfg)?;
        cfg.compressor_for_dim(problem.dim())?;
        let graph = cfg.topology()?;
        // auto-selects CSR on sparse graphs, so a `nodes` axis scales O(nnz)
        let mixing = MixingOp::build(&graph, cfg.mixing_rule()?);
        let eta = if cfg.eta > 0.0 { cfg.eta } else { 0.5 / problem.smoothness() };
        let hyper = Hyper { eta, alpha: cfg.alpha, gamma: cfg.gamma };
        let x0 = Mat::zeros(cfg.nodes, problem.dim());
        Ok(Experiment {
            config: cfg.clone(),
            kind,
            problem,
            graph,
            mixing,
            hyper,
            x0,
            x_star: OnceLock::new(),
        })
    }

    /// Swap the mixing operator (e.g. to pin dense ≡ CSR equivalence)
    /// while keeping every other resolved component identical.
    pub fn with_mixing(mut self, w: MixingOp) -> Experiment {
        assert_eq!(w.n(), self.config.nodes, "mixing operator size mismatch");
        self.mixing = w;
        self
    }

    // --- resolved component accessors (validated at construction) -------

    /// The configured stochastic gradient oracle.
    pub fn oracle(&self) -> OracleKind {
        self.config.oracle_kind().expect("oracle validated at construction")
    }

    /// A fresh compression operator (the `randk`/`topk` default budget is
    /// derived from the *resolved* parameter dimension).
    pub fn compressor(&self) -> Box<dyn Compressor> {
        self.config
            .compressor_for_dim(self.problem.dim())
            .expect("compressor validated at construction")
    }

    /// The shared non-smooth term r(x) (λ₁ > 0 ⇒ ℓ1, else zero).
    pub fn prox(&self) -> Box<dyn Prox> {
        self.config.prox()
    }

    /// Wire codec for the message-passing coordinator.
    pub fn codec(&self) -> WireCodec {
        self.config.codec().expect("codec validated at construction")
    }

    /// The resolved stepsize η (auto = 1/(2L) when the config says 0).
    pub fn eta(&self) -> f64 {
        self.hyper.eta
    }

    // --- reference solution ---------------------------------------------

    /// The high-precision reference x*, solved once per experiment (FISTA,
    /// [`REF_MAX_ITER`] / [`REF_TOL`]) and cached.
    pub fn reference(&self) -> Arc<Vec<f64>> {
        self.x_star
            .get_or_init(|| {
                Arc::new(solve_reference(
                    self.problem.as_ref(),
                    self.config.lambda1,
                    REF_MAX_ITER,
                    REF_TOL,
                ))
            })
            .clone()
    }

    /// Inject an externally cached x* (the sweep runtime shares one across
    /// cells with identical problems). No-op if already resolved.
    pub fn set_reference(&self, x_star: Arc<Vec<f64>>) {
        let _ = self.x_star.set(x_star);
    }

    // --- backends --------------------------------------------------------

    /// Instantiate the configured algorithm over this experiment, seeded
    /// with the config seed (see [`registry`] for the name table).
    pub fn algorithm(&self) -> Box<dyn Algorithm> {
        self.algorithm_with_seed(self.config.seed)
    }

    /// [`Experiment::algorithm`] with an explicit RNG seed (sweep cells
    /// derive theirs from the cell index).
    pub fn algorithm_with_seed(&self, seed: u64) -> Box<dyn Algorithm> {
        registry::build_algorithm(self, seed).expect("algorithm validated at construction")
    }

    /// Run controls matching the config (`rounds`, `record_every`) —
    /// extend with [`RunSpec`] combinators (`until`, `bits_budget`,
    /// `deadline`, …) before handing to either backend.
    pub fn run_spec(&self) -> RunSpec {
        RunSpec::fixed(self.config.rounds).every(self.config.record_every)
    }

    /// Drive the configured algorithm through the synchronous matrix
    /// engine, measuring against the cached reference. `spec.seed`
    /// overrides the config seed (sweep cells derive per-cell seeds).
    pub fn run(&self, spec: &RunSpec) -> RunResult {
        self.run_probed(spec, &mut [])
    }

    /// [`Experiment::run`] with streaming [`Probe`]s (live CSV, progress
    /// lines, custom per-round observers).
    pub fn run_probed(&self, spec: &RunSpec, probes: &mut [&mut dyn Probe]) -> RunResult {
        let mut alg = self.algorithm_with_seed(spec.seed.unwrap_or(self.config.seed));
        let x_star = self.reference();
        runner::run_engine(alg.as_mut(), self.problem.as_ref(), &x_star, spec, probes)
    }

    /// Wire-level coordinator knobs matching the config (codec, straggler
    /// model, seed). Rounds/sampling/stops travel in the [`RunSpec`].
    pub fn coord_config(&self) -> CoordConfig {
        let cfg = &self.config;
        let mut c = CoordConfig::new(self.codec()).seed(cfg.seed);
        if cfg.straggler_prob > 0.0 {
            c = c.straggler(Straggler {
                prob: cfg.straggler_prob,
                delay: Duration::from_micros(cfg.straggler_us),
            });
        }
        c
    }

    /// Drive the configured algorithm on node threads (the message-passing
    /// coordinator) under the same [`RunSpec`] vocabulary as
    /// [`Experiment::run`] — target/bits/evals/deadline stops reach the
    /// node threads through the leader's early-stop broadcast. Every
    /// `algorithm=` registry value runs here — the per-node halves are
    /// dispatched by [`registry::build_node_algorithm`].
    pub fn run_coordinator(&self, spec: &RunSpec) -> RunResult {
        self.run_coordinator_probed(spec, &mut [])
    }

    /// [`Experiment::run_coordinator`] with streaming [`Probe`]s. Honors
    /// the config's `transport` key: `inproc` spawns node threads; `tcp` /
    /// `unix` bind the config's `bind` address and wait for `proxlead
    /// node` worker processes to dial in (a bind failure panics with the
    /// config error — pre-flight with [`Experiment::bind_transport`] to
    /// handle it).
    pub fn run_coordinator_probed(
        &self,
        spec: &RunSpec,
        probes: &mut [&mut dyn Probe],
    ) -> RunResult {
        let transport = self.bind_transport().unwrap_or_else(|e| panic!("{e}"));
        self.run_coordinator_transport(spec, probes, transport)
    }

    /// [`Experiment::run_coordinator_probed`] over an explicit, already
    /// bound [`Transport`] (tests bind ephemeral listeners themselves).
    pub fn run_coordinator_transport(
        &self,
        spec: &RunSpec,
        probes: &mut [&mut dyn Probe],
        transport: Transport,
    ) -> RunResult {
        let mut wire = self.coord_config();
        if let Some(s) = spec.seed {
            wire.seed = s;
        }
        let x_star = self.reference();
        coordinator::run_with_transport(
            &self.mixing,
            &self.x0,
            &self.config.algorithm,
            &wire,
            spec,
            &x_star,
            probes,
            |i, row| registry::build_node_algorithm(self, &wire, i, row),
            transport,
        )
    }

    /// Config fingerprint for the socket handshake: FNV-1a over the
    /// canonical config rendering with the output path blanked (where a
    /// run's JSON lands must not stop machines from agreeing they run the
    /// same experiment). Leader and `proxlead node` workers must match.
    pub fn wire_fingerprint(&self) -> u64 {
        let mut c = self.config.clone();
        c.out = String::new();
        transport::fingerprint(&c.to_text())
    }

    /// Bind the configured transport: `inproc` needs no resources; `tcp`
    /// and `unix` bind the leader's listener at the config's `bind`
    /// address. The fallible half of a socket run, split out so callers
    /// can surface bind errors as config errors instead of panics.
    pub fn bind_transport(&self) -> Result<Transport, ConfigError> {
        let cfg = &self.config;
        // workers get connect_timeout_ms of dial budget; the leader's
        // accept loop waits twice that (1s floor for ephemeral-port tests)
        let accept = Duration::from_millis(cfg.connect_timeout_ms.saturating_mul(2).max(1000));
        let fp = self.wire_fingerprint();
        match cfg.transport.as_str() {
            "inproc" => Ok(Transport::InProc),
            "tcp" => {
                let l = std::net::TcpListener::bind(&cfg.bind)
                    .map_err(|e| ConfigError(format!("bind {}: {e}", cfg.bind)))?;
                Ok(Transport::tcp(l, fp, accept))
            }
            "unix" => {
                // a stale socket file from a dead leader would fail the
                // bind; the path is ours by configuration
                let _ = std::fs::remove_file(&cfg.bind);
                let l = std::os::unix::net::UnixListener::bind(&cfg.bind)
                    .map_err(|e| ConfigError(format!("bind {}: {e}", cfg.bind)))?;
                Ok(Transport::unix(l, fp, accept))
            }
            t => Err(ConfigError(format!("unknown transport '{t}' (inproc | tcp | unix)"))),
        }
    }

    /// Run ONE node's half of a socket-coordinator run: dial the leader at
    /// the config's `bind` address (bounded retry while the leader is
    /// still binding), handshake as `node`, then drive the configured
    /// algorithm over the socket link until BYE/ABORT. This is what
    /// `proxlead node --node-id i` executes, once per worker process; the
    /// leader assembles the [`RunResult`].
    pub fn run_node_worker(&self, spec: &RunSpec, node: usize) -> Result<(), ConfigError> {
        let cfg = &self.config;
        let addr = match cfg.transport.as_str() {
            "tcp" => socket::DialAddr::Tcp(cfg.bind.clone()),
            "unix" => socket::DialAddr::Unix(std::path::PathBuf::from(&cfg.bind)),
            t => {
                return Err(ConfigError(format!(
                    "transport = {t} has no node workers (use tcp or unix)"
                )))
            }
        };
        self.run_node_worker_at(spec, node, &addr)
    }

    /// [`Experiment::run_node_worker`] with an explicit dial address (the
    /// loopback harness dials an ephemeral port the OS picked).
    pub fn run_node_worker_at(
        &self,
        spec: &RunSpec,
        node: usize,
        addr: &socket::DialAddr,
    ) -> Result<(), ConfigError> {
        let n = self.mixing.n();
        if node >= n {
            return Err(ConfigError(format!("node id {node} out of range (nodes = {n})")));
        }
        let mut wire = self.coord_config();
        if let Some(s) = spec.seed {
            wire.seed = s;
        }
        let hello = Hello {
            fingerprint: self.wire_fingerprint(),
            n: n as u32,
            dim: self.problem.dim() as u32,
            rounds: spec.stop.max_rounds as u32,
            record_every: spec.record_every as u32,
            gated: spec.stop.leader_gated(),
        };
        let timeout = Duration::from_millis(self.config.connect_timeout_ms.max(1));
        let link = socket::dial(addr, node as u16, &hello, timeout)
            .map_err(|e| ConfigError(format!("node {node}: dial {addr:?}: {e}")))?;
        let row = WeightRow::from_op(&self.mixing, node);
        let neighbors: Vec<usize> = row.neighbors.iter().map(|&(j, _)| j).collect();
        let alg = registry::build_node_algorithm(self, &wire, node, row);
        run_node(
            alg,
            NodeConfig {
                id: node,
                neighbors,
                link: Box::new(link),
                wire,
                rounds: spec.stop.max_rounds,
                record_every: spec.record_every,
                dim: self.problem.dim(),
            },
        );
        Ok(())
    }

    /// Loopback socket harness: bind an ephemeral listener (tcp on
    /// 127.0.0.1:0, unix on a unique temp path), run every node worker on
    /// an in-process thread, and drive the leader — a complete
    /// socket-transport run inside one process. The transport parity tests
    /// and the wire-bytes bench use this; real deployments run `proxlead
    /// node` worker processes instead. `kind` is `"tcp"` or `"unix"`.
    pub fn run_coordinator_loopback(&self, spec: &RunSpec, kind: &str) -> RunResult {
        let accept = Duration::from_secs(30);
        let fp = self.wire_fingerprint();
        let (transport, addr) = match kind {
            "tcp" => {
                let l = std::net::TcpListener::bind("127.0.0.1:0").expect("bind loopback tcp");
                let addr = l.local_addr().expect("loopback local addr").to_string();
                (Transport::tcp(l, fp, accept), socket::DialAddr::Tcp(addr))
            }
            "unix" => {
                let path = loopback_socket_path();
                let _ = std::fs::remove_file(&path);
                let l =
                    std::os::unix::net::UnixListener::bind(&path).expect("bind loopback unix");
                (Transport::unix(l, fp, accept), socket::DialAddr::Unix(path))
            }
            t => panic!("loopback transport must be tcp or unix (got {t})"),
        };
        let n = self.mixing.n();
        let res = std::thread::scope(|scope| {
            for i in 0..n {
                let addr = addr.clone();
                scope.spawn(move || {
                    // a worker that fails to dial shows up leader-side as
                    // a HandshakeTimeout fault — nothing to do here
                    let _ = self.run_node_worker_at(spec, i, &addr);
                });
            }
            self.run_coordinator_transport(spec, &mut [], transport)
        });
        if let socket::DialAddr::Unix(p) = &addr {
            let _ = std::fs::remove_file(p);
        }
        res
    }

    /// Drive the configured algorithm through the event-driven massive-n
    /// simulation backend ([`crate::sim`]): the same per-node halves and
    /// wire codec path as the coordinator, but on a fixed sharded worker
    /// pool instead of one thread per node — n = 100k–1M nodes in
    /// O(nnz + n·d) memory. Bit-identical to both other backends under
    /// `Dense64` (`rust/tests/sim_parity.rs`).
    pub fn run_sim(&self, spec: &RunSpec) -> RunResult {
        self.run_sim_probed(spec, &mut [])
    }

    /// [`Experiment::run_sim`] with streaming [`Probe`]s.
    pub fn run_sim_probed(&self, spec: &RunSpec, probes: &mut [&mut dyn Probe]) -> RunResult {
        let mut wire = self.coord_config();
        if let Some(s) = spec.seed {
            wire.seed = s;
        }
        let x_star = self.reference();
        sim::run(
            &self.mixing,
            &self.x0,
            &self.config.algorithm,
            &wire,
            spec,
            &x_star,
            probes,
            |i, row| registry::build_node_algorithm(self, &wire, i, row),
        )
    }

    /// Dispatch on the config's `backend` key (`engine` | `coordinator` |
    /// `sim`, validated at construction) — the one entry point `proxlead
    /// train` and the sweep runtime call, so `backend` is a grid axis like
    /// any other config key.
    pub fn run_backend(&self, spec: &RunSpec) -> RunResult {
        self.run_backend_probed(spec, &mut [])
    }

    /// [`Experiment::run_backend`] with streaming [`Probe`]s.
    pub fn run_backend_probed(&self, spec: &RunSpec, probes: &mut [&mut dyn Probe]) -> RunResult {
        match self.config.backend.as_str() {
            "coordinator" => self.run_coordinator_probed(spec, probes),
            "sim" => self.run_sim_probed(spec, probes),
            // "engine", enforced by ensure_backend at construction
            _ => self.run_probed(spec, probes),
        }
    }
}

/// A collision-free unix socket path for a loopback run: process id plus
/// a per-process counter (no clocks, no randomness — see clippy.toml).
fn loopback_socket_path() -> std::path::PathBuf {
    static SEQ: std::sync::atomic::AtomicUsize = std::sync::atomic::AtomicUsize::new(0);
    let k = SEQ.fetch_add(1, std::sync::atomic::Ordering::AcqRel);
    std::env::temp_dir().join(format!("proxlead-loop-{}-{k}.sock", std::process::id()))
}

/// The factory checks shared by [`validate_config`] and
/// [`Experiment::from_config`]'s assembly — one checklist, so the two
/// paths cannot drift (a factory validated here is safe to `expect()` in
/// the accessors; a factory added to assembly must be added here).
fn validate_runtime_factories(cfg: &Config) -> Result<(), ConfigError> {
    cfg.mixing_rule()?;
    cfg.oracle_kind()?;
    cfg.codec()?;
    registry::ensure_backend(&cfg.backend)?;
    // the sim and the coordinator share one frame format, whose `from`
    // field is a u16 — reject instead of silently truncating sender ids in
    // WireFault reports (the arithmetic never routes on the id)
    if (cfg.backend == "sim" || cfg.backend == "coordinator") && cfg.nodes > u16::MAX as usize {
        return Err(ConfigError(format!(
            "backend = {} supports at most 65535 nodes (frame sender ids are u16 on the \
             wire); got nodes = {}",
            cfg.backend, cfg.nodes
        )));
    }
    match cfg.transport.as_str() {
        "inproc" => {}
        "tcp" | "unix" => {
            if cfg.backend != "coordinator" {
                return Err(ConfigError(format!(
                    "transport = {} requires backend = coordinator (got backend = {})",
                    cfg.transport, cfg.backend
                )));
            }
            if cfg.bind.is_empty() {
                return Err(ConfigError(format!(
                    "transport = {} needs a bind address (`bind = host:port` for tcp, a \
                     socket path for unix)",
                    cfg.transport
                )));
            }
        }
        t => return Err(ConfigError(format!("unknown transport '{t}' (inproc | tcp | unix)"))),
    }
    registry::ensure_algorithm(&cfg.algorithm)
}

/// Cheap, problem-construction-free validation of a configuration — every
/// factory the runtime will call, without generating data. The sweep
/// runtime validates whole grids up front with this before fanning out.
pub fn validate_config(cfg: &Config) -> Result<(), ConfigError> {
    cfg.problem_kind()?;
    registry::check_problem_shape(cfg)?;
    cfg.topology()?;
    cfg.compressor()?;
    validate_runtime_factories(cfg)
}

/// Builds an [`Experiment`] from chained config overrides — the ergonomic
/// front door for examples and library users:
///
/// ```text
/// let exp = Experiment::builder()
///     .problem("least-squares")
///     .nodes(8)
///     .set("bits", "2")
///     .build()?;
/// let trace = exp.run(&exp.run_spec());
/// ```
pub struct ExperimentBuilder {
    cfg: Config,
    overrides: Vec<(String, String)>,
    problem: Option<Arc<dyn Problem>>,
}

impl Default for ExperimentBuilder {
    fn default() -> ExperimentBuilder {
        ExperimentBuilder::new()
    }
}

impl ExperimentBuilder {
    pub fn new() -> ExperimentBuilder {
        ExperimentBuilder::from_config(Config::default())
    }

    /// Start from an existing configuration (e.g. a parsed file).
    pub fn from_config(cfg: Config) -> ExperimentBuilder {
        ExperimentBuilder { cfg, overrides: Vec::new(), problem: None }
    }

    /// Queue one `key = value` override (any config key; applied in order
    /// at [`ExperimentBuilder::build`], where bad keys/values error).
    pub fn set(mut self, key: &str, val: &str) -> ExperimentBuilder {
        self.overrides.push((key.to_string(), val.to_string()));
        self
    }

    /// Inject a caller-built problem instead of the registry's synthetic
    /// one. `nodes` must match the problem's node count.
    pub fn with_problem(mut self, problem: Arc<dyn Problem>) -> ExperimentBuilder {
        self.problem = Some(problem);
        self
    }

    // typed conveniences over the most common keys --------------------------

    pub fn problem(self, kind: &str) -> ExperimentBuilder {
        self.set("problem", kind)
    }

    pub fn algorithm(self, name: &str) -> ExperimentBuilder {
        self.set("algorithm", name)
    }

    pub fn topology(self, name: &str) -> ExperimentBuilder {
        self.set("topology", name)
    }

    pub fn oracle(self, name: &str) -> ExperimentBuilder {
        self.set("oracle", name)
    }

    pub fn nodes(self, n: usize) -> ExperimentBuilder {
        self.set("nodes", &n.to_string())
    }

    pub fn bits(self, b: u32) -> ExperimentBuilder {
        self.set("bits", &b.to_string())
    }

    pub fn rounds(self, r: usize) -> ExperimentBuilder {
        self.set("rounds", &r.to_string())
    }

    pub fn seed(self, s: u64) -> ExperimentBuilder {
        self.set("seed", &s.to_string())
    }

    pub fn eta(self, eta: f64) -> ExperimentBuilder {
        self.set("eta", &eta.to_string())
    }

    pub fn lambda1(self, l1: f64) -> ExperimentBuilder {
        self.set("lambda1", &l1.to_string())
    }

    pub fn lambda2(self, l2: f64) -> ExperimentBuilder {
        self.set("lambda2", &l2.to_string())
    }

    /// Apply the overrides and resolve. All configuration errors (unknown
    /// keys, bad values, unresolvable factories) surface here.
    pub fn build(self) -> Result<Experiment, ConfigError> {
        let mut cfg = self.cfg;
        for (k, v) in &self.overrides {
            cfg.set(k, v)?;
        }
        match self.problem {
            Some(p) => Experiment::from_config_with_problem(&cfg, p),
            None => Experiment::from_config(&cfg),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::problem::ProblemKind;

    fn tiny(problem: &str) -> Config {
        Config::parse(&format!(
            "problem = {problem}\nnodes = 4\nsamples_per_node = 24\ndim = 6\nclasses = 3\n\
             batches = 4\nlambda1 = 0.005\nlambda2 = 0.1\nrounds = 40\nrecord_every = 10\n"
        ))
        .expect("tiny config")
    }

    #[test]
    fn from_config_resolves_every_component() {
        let exp = Experiment::from_config(&tiny("logreg")).unwrap();
        assert_eq!(exp.kind, ProblemKind::LogReg);
        assert_eq!(exp.problem.num_nodes(), 4);
        assert_eq!(exp.problem.dim(), 6 * 3);
        assert_eq!(exp.x0.rows, 4);
        assert_eq!(exp.x0.cols, 18);
        assert_eq!(exp.mixing.n(), 4);
        // auto-η resolved here, once
        assert!((exp.hyper.eta - 0.5 / exp.problem.smoothness()).abs() < 1e-15);
        assert_eq!(exp.hyper.alpha, 0.5);
        assert_eq!(exp.compressor().name(), "2bit");
        assert_eq!(exp.prox().name(), "l1(0.005)");
    }

    #[test]
    fn explicit_eta_wins_over_auto() {
        let mut cfg = tiny("logreg");
        cfg.eta = 0.07;
        let exp = Experiment::from_config(&cfg).unwrap();
        assert_eq!(exp.hyper.eta, 0.07);
    }

    #[test]
    fn least_squares_and_lasso_resolve() {
        for (name, kind) in
            [("least-squares", ProblemKind::LeastSquares), ("lasso", ProblemKind::Lasso)]
        {
            let exp = Experiment::from_config(&tiny(name)).unwrap();
            assert_eq!(exp.kind, kind);
            // regression problems are p = dim (no class flattening)
            assert_eq!(exp.problem.dim(), 6);
            assert!(exp.problem.smoothness().is_finite());
            assert!(exp.problem.strong_convexity() > 0.0);
        }
    }

    #[test]
    fn reference_is_cached_and_injectable() {
        let exp = Experiment::from_config(&tiny("logreg")).unwrap();
        let a = exp.reference();
        let b = exp.reference();
        assert!(Arc::ptr_eq(&a, &b));
        // injection after the fact is a no-op
        exp.set_reference(Arc::new(vec![0.0; exp.problem.dim()]));
        assert!(Arc::ptr_eq(&exp.reference(), &a));
        // injection before first use wins
        let exp2 = Experiment::from_config(&tiny("logreg")).unwrap();
        exp2.set_reference(Arc::clone(&a));
        assert!(Arc::ptr_eq(&exp2.reference(), &a));
    }

    #[test]
    fn run_drives_the_engine() {
        let exp = Experiment::from_config(&tiny("logreg")).unwrap();
        let res = exp.run(&exp.run_spec());
        assert_eq!(res.history.last().unwrap().round, 40);
        assert!(res.final_subopt().is_finite());
        assert!(res.name.starts_with("Prox-LEAD"));
        assert_eq!(res.backend, crate::runner::Backend::Engine);
        assert_eq!(res.stopped_by, crate::runner::StopReason::MaxRounds);
    }

    #[test]
    fn builder_routes_overrides_and_errors() {
        let exp = Experiment::builder()
            .problem("least-squares")
            .nodes(4)
            .set("samples_per_node", "24")
            .set("dim", "6")
            .set("batches", "4")
            .build()
            .unwrap();
        assert_eq!(exp.kind, ProblemKind::LeastSquares);
        assert!(Experiment::builder().set("warp_drive", "on").build().is_err());
        assert!(Experiment::builder().set("problem", "sudoku").build().is_err());
        assert!(Experiment::builder().algorithm("gradient-descent-but-wrong").build().is_err());
    }

    #[test]
    fn validate_config_is_cheap_and_strict() {
        assert!(validate_config(&tiny("logreg")).is_ok());
        assert!(validate_config(&tiny("lasso")).is_ok());
        let mut bad = tiny("logreg");
        bad.algorithm = "nope".into();
        assert!(validate_config(&bad).is_err());
        let mut bad = tiny("logreg");
        bad.samples_per_node = 25; // not divisible into 4 batches
        assert!(validate_config(&bad).is_err());
        let mut bad = tiny("logreg");
        bad.backend = "tpu".into();
        assert!(validate_config(&bad).is_err());
        let mut bad = tiny("logreg");
        bad.compute = "tpu".into();
        assert!(validate_config(&bad).is_err());
    }

    #[test]
    fn custom_problem_injection_checks_node_count() {
        let (shards, _) = crate::problem::data::sparse_regression(4, 24, 8, 3, 0.05, 5);
        let p: Arc<dyn Problem> = Arc::new(crate::problem::LeastSquares::new(shards, 1e-2, 4));
        let ok = ExperimentBuilder::new()
            .nodes(4)
            .set("samples_per_node", "24")
            .with_problem(Arc::clone(&p))
            .build();
        assert!(ok.is_ok());
        assert_eq!(ok.unwrap().problem.dim(), 8);
        let bad = ExperimentBuilder::new().nodes(8).with_problem(p).build();
        assert!(bad.unwrap_err().0.contains("nodes"));
    }
}
