//! The one-experiment API: `Config → Experiment` resolved in exactly one
//! place.
//!
//! Every entry point — the CLI (`train` / `sweep` / `info` / `solve-ref`),
//! the sweep runtime, the figure/table benches, and the examples — used to
//! re-implement config resolution by hand (problem construction, graph +
//! mixing operator, auto-η, compressor, prox, reference solve). This
//! module is the single pipeline:
//!
//! ```text
//! Config (key = value file / --key overrides)
//!    │  Experiment::from_config            — the ONE resolution pipeline
//!    ▼
//! Experiment {
//!    problem: Arc<dyn Problem>   ← problem registry (logreg |
//!                                   least-squares | lasso)
//!    graph → mixing: MixingOp    ← topology × rule, dense|CSR auto
//!    hyper: Hyper                ← auto-η = 1/(2L) resolved HERE
//!    x0, compressor, prox, oracle, cached reference x*
//! }
//!    │
//!    ├── experiment.algorithm()   → Box<dyn Algorithm>   (registry +
//!    │                              typed builders, see [`registry`])
//!    ├── experiment.run(&RunSpec)             → matrix engine
//!    └── experiment.run_coordinator(&RunSpec) → node threads + wire frames
//! ```
//!
//! Both backends speak the one run vocabulary of [`crate::runner`]
//! (composable stop criteria, streaming probes, unified `RunResult`).
//!
//! Adding a scenario (a new problem family, algorithm, topology, or
//! compressor) means registering it once here — every sweep axis, bench,
//! and CLI flag picks it up automatically.

pub mod registry;

pub use registry::{build_problem, ALGORITHM_NAMES};

use crate::algorithm::{solve_reference, Algorithm, Hyper};
use crate::compress::Compressor;
use crate::config::{Config, ConfigError};
use crate::coordinator::{self, CoordConfig, Straggler, WireCodec};
use crate::graph::{Graph, MixingOp};
use crate::linalg::Mat;
use crate::oracle::OracleKind;
use crate::problem::{Problem, ProblemKind};
use crate::prox::Prox;
use crate::runner::{self, Probe, RunResult, RunSpec};
use crate::sim;
use std::sync::{Arc, OnceLock};
use std::time::Duration;

/// Reference-solution budget shared by every resolved experiment — the
/// figure benches' historical 80k-iteration FISTA budget, so even the most
/// ill-conditioned grid cells converge their x* well below the 1e-9
/// measurement targets (FISTA early-stops at the tolerance, so
/// well-conditioned problems pay far less).
pub const REF_MAX_ITER: usize = 80_000;
pub const REF_TOL: f64 = 1e-12;

/// A fully resolved experiment: everything a backend needs, constructed
/// from a [`Config`] by [`Experiment::from_config`] and nowhere else.
///
/// Fields are public so tests and benches can substitute single components
/// (e.g. a dense vs CSR mixing operator via [`Experiment::with_mixing`])
/// while keeping the rest of the resolution identical.
#[derive(Clone)]
pub struct Experiment {
    /// The source configuration (validated: every factory below resolves).
    pub config: Config,
    /// The config-declared problem family (callers injecting a custom
    /// problem via [`ExperimentBuilder::with_problem`] may ignore it).
    pub kind: ProblemKind,
    pub problem: Arc<dyn Problem>,
    pub graph: Graph,
    pub mixing: MixingOp,
    /// Hyperparameters with η resolved (config 0 ⇒ auto 1/(2L)).
    pub hyper: Hyper,
    /// Common start iterate X⁰ = 0 (n × p).
    pub x0: Mat,
    /// Cached high-precision reference x* (λ₁-regularized FISTA).
    x_star: OnceLock<Arc<Vec<f64>>>,
}

impl Experiment {
    /// The single `Config → Experiment` resolution pipeline. Validates
    /// every factory once, so the accessors below are infallible.
    pub fn from_config(cfg: &Config) -> Result<Experiment, ConfigError> {
        let kind = cfg.problem_kind()?;
        let problem = registry::build_problem(cfg)?;
        Experiment::assemble(cfg, kind, problem)
    }

    /// [`Experiment::from_config`] with a caller-built problem instead of
    /// the registry's synthetic one (custom data, wrapped backends).
    /// `config.nodes` must match the problem's node count.
    pub fn from_config_with_problem(
        cfg: &Config,
        problem: Arc<dyn Problem>,
    ) -> Result<Experiment, ConfigError> {
        let kind = cfg.problem_kind()?;
        Experiment::assemble(cfg, kind, problem)
    }

    /// Start a builder over the default configuration.
    pub fn builder() -> ExperimentBuilder {
        ExperimentBuilder::new()
    }

    fn assemble(
        cfg: &Config,
        kind: ProblemKind,
        problem: Arc<dyn Problem>,
    ) -> Result<Experiment, ConfigError> {
        if problem.num_nodes() != cfg.nodes {
            return Err(ConfigError(format!(
                "problem has {} nodes but the config says nodes = {}",
                problem.num_nodes(),
                cfg.nodes
            )));
        }
        // one shared factory checklist (also what validate_config runs),
        // so the accessors below can unwrap safely
        validate_runtime_factories(cfg)?;
        cfg.compressor_for_dim(problem.dim())?;
        let graph = cfg.topology()?;
        // auto-selects CSR on sparse graphs, so a `nodes` axis scales O(nnz)
        let mixing = MixingOp::build(&graph, cfg.mixing_rule()?);
        let eta = if cfg.eta > 0.0 { cfg.eta } else { 0.5 / problem.smoothness() };
        let hyper = Hyper { eta, alpha: cfg.alpha, gamma: cfg.gamma };
        let x0 = Mat::zeros(cfg.nodes, problem.dim());
        Ok(Experiment {
            config: cfg.clone(),
            kind,
            problem,
            graph,
            mixing,
            hyper,
            x0,
            x_star: OnceLock::new(),
        })
    }

    /// Swap the mixing operator (e.g. to pin dense ≡ CSR equivalence)
    /// while keeping every other resolved component identical.
    pub fn with_mixing(mut self, w: MixingOp) -> Experiment {
        assert_eq!(w.n(), self.config.nodes, "mixing operator size mismatch");
        self.mixing = w;
        self
    }

    // --- resolved component accessors (validated at construction) -------

    /// The configured stochastic gradient oracle.
    pub fn oracle(&self) -> OracleKind {
        self.config.oracle_kind().expect("oracle validated at construction")
    }

    /// A fresh compression operator (the `randk`/`topk` default budget is
    /// derived from the *resolved* parameter dimension).
    pub fn compressor(&self) -> Box<dyn Compressor> {
        self.config
            .compressor_for_dim(self.problem.dim())
            .expect("compressor validated at construction")
    }

    /// The shared non-smooth term r(x) (λ₁ > 0 ⇒ ℓ1, else zero).
    pub fn prox(&self) -> Box<dyn Prox> {
        self.config.prox()
    }

    /// Wire codec for the message-passing coordinator.
    pub fn codec(&self) -> WireCodec {
        self.config.codec().expect("codec validated at construction")
    }

    /// The resolved stepsize η (auto = 1/(2L) when the config says 0).
    pub fn eta(&self) -> f64 {
        self.hyper.eta
    }

    // --- reference solution ---------------------------------------------

    /// The high-precision reference x*, solved once per experiment (FISTA,
    /// [`REF_MAX_ITER`] / [`REF_TOL`]) and cached.
    pub fn reference(&self) -> Arc<Vec<f64>> {
        self.x_star
            .get_or_init(|| {
                Arc::new(solve_reference(
                    self.problem.as_ref(),
                    self.config.lambda1,
                    REF_MAX_ITER,
                    REF_TOL,
                ))
            })
            .clone()
    }

    /// Inject an externally cached x* (the sweep runtime shares one across
    /// cells with identical problems). No-op if already resolved.
    pub fn set_reference(&self, x_star: Arc<Vec<f64>>) {
        let _ = self.x_star.set(x_star);
    }

    // --- backends --------------------------------------------------------

    /// Instantiate the configured algorithm over this experiment, seeded
    /// with the config seed (see [`registry`] for the name table).
    pub fn algorithm(&self) -> Box<dyn Algorithm> {
        self.algorithm_with_seed(self.config.seed)
    }

    /// [`Experiment::algorithm`] with an explicit RNG seed (sweep cells
    /// derive theirs from the cell index).
    pub fn algorithm_with_seed(&self, seed: u64) -> Box<dyn Algorithm> {
        registry::build_algorithm(self, seed).expect("algorithm validated at construction")
    }

    /// Run controls matching the config (`rounds`, `record_every`) —
    /// extend with [`RunSpec`] combinators (`until`, `bits_budget`,
    /// `deadline`, …) before handing to either backend.
    pub fn run_spec(&self) -> RunSpec {
        RunSpec::fixed(self.config.rounds).every(self.config.record_every)
    }

    /// Drive the configured algorithm through the synchronous matrix
    /// engine, measuring against the cached reference. `spec.seed`
    /// overrides the config seed (sweep cells derive per-cell seeds).
    pub fn run(&self, spec: &RunSpec) -> RunResult {
        self.run_probed(spec, &mut [])
    }

    /// [`Experiment::run`] with streaming [`Probe`]s (live CSV, progress
    /// lines, custom per-round observers).
    pub fn run_probed(&self, spec: &RunSpec, probes: &mut [&mut dyn Probe]) -> RunResult {
        let mut alg = self.algorithm_with_seed(spec.seed.unwrap_or(self.config.seed));
        let x_star = self.reference();
        runner::run_engine(alg.as_mut(), self.problem.as_ref(), &x_star, spec, probes)
    }

    /// Wire-level coordinator knobs matching the config (codec, straggler
    /// model, seed). Rounds/sampling/stops travel in the [`RunSpec`].
    pub fn coord_config(&self) -> CoordConfig {
        let cfg = &self.config;
        let mut c = CoordConfig::new(self.codec()).seed(cfg.seed);
        if cfg.straggler_prob > 0.0 {
            c = c.straggler(Straggler {
                prob: cfg.straggler_prob,
                delay: Duration::from_micros(cfg.straggler_us),
            });
        }
        c
    }

    /// Drive the configured algorithm on node threads (the message-passing
    /// coordinator) under the same [`RunSpec`] vocabulary as
    /// [`Experiment::run`] — target/bits/evals/deadline stops reach the
    /// node threads through the leader's early-stop broadcast. Every
    /// `algorithm=` registry value runs here — the per-node halves are
    /// dispatched by [`registry::build_node_algorithm`].
    pub fn run_coordinator(&self, spec: &RunSpec) -> RunResult {
        self.run_coordinator_probed(spec, &mut [])
    }

    /// [`Experiment::run_coordinator`] with streaming [`Probe`]s.
    pub fn run_coordinator_probed(
        &self,
        spec: &RunSpec,
        probes: &mut [&mut dyn Probe],
    ) -> RunResult {
        let mut wire = self.coord_config();
        if let Some(s) = spec.seed {
            wire.seed = s;
        }
        let x_star = self.reference();
        coordinator::run(
            &self.mixing,
            &self.x0,
            &self.config.algorithm,
            &wire,
            spec,
            &x_star,
            probes,
            |i, row| registry::build_node_algorithm(self, &wire, i, row),
        )
    }

    /// Drive the configured algorithm through the event-driven massive-n
    /// simulation backend ([`crate::sim`]): the same per-node halves and
    /// wire codec path as the coordinator, but on a fixed sharded worker
    /// pool instead of one thread per node — n = 100k–1M nodes in
    /// O(nnz + n·d) memory. Bit-identical to both other backends under
    /// `Dense64` (`rust/tests/sim_parity.rs`).
    pub fn run_sim(&self, spec: &RunSpec) -> RunResult {
        self.run_sim_probed(spec, &mut [])
    }

    /// [`Experiment::run_sim`] with streaming [`Probe`]s.
    pub fn run_sim_probed(&self, spec: &RunSpec, probes: &mut [&mut dyn Probe]) -> RunResult {
        let mut wire = self.coord_config();
        if let Some(s) = spec.seed {
            wire.seed = s;
        }
        let x_star = self.reference();
        sim::run(
            &self.mixing,
            &self.x0,
            &self.config.algorithm,
            &wire,
            spec,
            &x_star,
            probes,
            |i, row| registry::build_node_algorithm(self, &wire, i, row),
        )
    }

    /// Dispatch on the config's `backend` key (`engine` | `coordinator` |
    /// `sim`, validated at construction) — the one entry point `proxlead
    /// train` and the sweep runtime call, so `backend` is a grid axis like
    /// any other config key.
    pub fn run_backend(&self, spec: &RunSpec) -> RunResult {
        self.run_backend_probed(spec, &mut [])
    }

    /// [`Experiment::run_backend`] with streaming [`Probe`]s.
    pub fn run_backend_probed(&self, spec: &RunSpec, probes: &mut [&mut dyn Probe]) -> RunResult {
        match self.config.backend.as_str() {
            "coordinator" => self.run_coordinator_probed(spec, probes),
            "sim" => self.run_sim_probed(spec, probes),
            // "engine", enforced by ensure_backend at construction
            _ => self.run_probed(spec, probes),
        }
    }
}

/// The factory checks shared by [`validate_config`] and
/// [`Experiment::from_config`]'s assembly — one checklist, so the two
/// paths cannot drift (a factory validated here is safe to `expect()` in
/// the accessors; a factory added to assembly must be added here).
fn validate_runtime_factories(cfg: &Config) -> Result<(), ConfigError> {
    cfg.mixing_rule()?;
    cfg.oracle_kind()?;
    cfg.codec()?;
    registry::ensure_backend(&cfg.backend)?;
    // the sim shares the coordinator's frame format, whose `from` field is
    // a u16 — reject instead of silently truncating sender ids in
    // WireFault reports (the arithmetic never routes on the id)
    if cfg.backend == "sim" && cfg.nodes > u16::MAX as usize {
        return Err(ConfigError(format!(
            "backend = sim supports at most 65535 nodes (frame sender ids are u16 on the \
             wire); got nodes = {}",
            cfg.nodes
        )));
    }
    registry::ensure_algorithm(&cfg.algorithm)
}

/// Cheap, problem-construction-free validation of a configuration — every
/// factory the runtime will call, without generating data. The sweep
/// runtime validates whole grids up front with this before fanning out.
pub fn validate_config(cfg: &Config) -> Result<(), ConfigError> {
    cfg.problem_kind()?;
    registry::check_problem_shape(cfg)?;
    cfg.topology()?;
    cfg.compressor()?;
    validate_runtime_factories(cfg)
}

/// Builds an [`Experiment`] from chained config overrides — the ergonomic
/// front door for examples and library users:
///
/// ```text
/// let exp = Experiment::builder()
///     .problem("least-squares")
///     .nodes(8)
///     .set("bits", "2")
///     .build()?;
/// let trace = exp.run(&exp.run_spec());
/// ```
pub struct ExperimentBuilder {
    cfg: Config,
    overrides: Vec<(String, String)>,
    problem: Option<Arc<dyn Problem>>,
}

impl Default for ExperimentBuilder {
    fn default() -> ExperimentBuilder {
        ExperimentBuilder::new()
    }
}

impl ExperimentBuilder {
    pub fn new() -> ExperimentBuilder {
        ExperimentBuilder::from_config(Config::default())
    }

    /// Start from an existing configuration (e.g. a parsed file).
    pub fn from_config(cfg: Config) -> ExperimentBuilder {
        ExperimentBuilder { cfg, overrides: Vec::new(), problem: None }
    }

    /// Queue one `key = value` override (any config key; applied in order
    /// at [`ExperimentBuilder::build`], where bad keys/values error).
    pub fn set(mut self, key: &str, val: &str) -> ExperimentBuilder {
        self.overrides.push((key.to_string(), val.to_string()));
        self
    }

    /// Inject a caller-built problem instead of the registry's synthetic
    /// one. `nodes` must match the problem's node count.
    pub fn with_problem(mut self, problem: Arc<dyn Problem>) -> ExperimentBuilder {
        self.problem = Some(problem);
        self
    }

    // typed conveniences over the most common keys --------------------------

    pub fn problem(self, kind: &str) -> ExperimentBuilder {
        self.set("problem", kind)
    }

    pub fn algorithm(self, name: &str) -> ExperimentBuilder {
        self.set("algorithm", name)
    }

    pub fn topology(self, name: &str) -> ExperimentBuilder {
        self.set("topology", name)
    }

    pub fn oracle(self, name: &str) -> ExperimentBuilder {
        self.set("oracle", name)
    }

    pub fn nodes(self, n: usize) -> ExperimentBuilder {
        self.set("nodes", &n.to_string())
    }

    pub fn bits(self, b: u32) -> ExperimentBuilder {
        self.set("bits", &b.to_string())
    }

    pub fn rounds(self, r: usize) -> ExperimentBuilder {
        self.set("rounds", &r.to_string())
    }

    pub fn seed(self, s: u64) -> ExperimentBuilder {
        self.set("seed", &s.to_string())
    }

    pub fn eta(self, eta: f64) -> ExperimentBuilder {
        self.set("eta", &eta.to_string())
    }

    pub fn lambda1(self, l1: f64) -> ExperimentBuilder {
        self.set("lambda1", &l1.to_string())
    }

    pub fn lambda2(self, l2: f64) -> ExperimentBuilder {
        self.set("lambda2", &l2.to_string())
    }

    /// Apply the overrides and resolve. All configuration errors (unknown
    /// keys, bad values, unresolvable factories) surface here.
    pub fn build(self) -> Result<Experiment, ConfigError> {
        let mut cfg = self.cfg;
        for (k, v) in &self.overrides {
            cfg.set(k, v)?;
        }
        match self.problem {
            Some(p) => Experiment::from_config_with_problem(&cfg, p),
            None => Experiment::from_config(&cfg),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::problem::ProblemKind;

    fn tiny(problem: &str) -> Config {
        Config::parse(&format!(
            "problem = {problem}\nnodes = 4\nsamples_per_node = 24\ndim = 6\nclasses = 3\n\
             batches = 4\nlambda1 = 0.005\nlambda2 = 0.1\nrounds = 40\nrecord_every = 10\n"
        ))
        .expect("tiny config")
    }

    #[test]
    fn from_config_resolves_every_component() {
        let exp = Experiment::from_config(&tiny("logreg")).unwrap();
        assert_eq!(exp.kind, ProblemKind::LogReg);
        assert_eq!(exp.problem.num_nodes(), 4);
        assert_eq!(exp.problem.dim(), 6 * 3);
        assert_eq!(exp.x0.rows, 4);
        assert_eq!(exp.x0.cols, 18);
        assert_eq!(exp.mixing.n(), 4);
        // auto-η resolved here, once
        assert!((exp.hyper.eta - 0.5 / exp.problem.smoothness()).abs() < 1e-15);
        assert_eq!(exp.hyper.alpha, 0.5);
        assert_eq!(exp.compressor().name(), "2bit");
        assert_eq!(exp.prox().name(), "l1(0.005)");
    }

    #[test]
    fn explicit_eta_wins_over_auto() {
        let mut cfg = tiny("logreg");
        cfg.eta = 0.07;
        let exp = Experiment::from_config(&cfg).unwrap();
        assert_eq!(exp.hyper.eta, 0.07);
    }

    #[test]
    fn least_squares_and_lasso_resolve() {
        for (name, kind) in
            [("least-squares", ProblemKind::LeastSquares), ("lasso", ProblemKind::Lasso)]
        {
            let exp = Experiment::from_config(&tiny(name)).unwrap();
            assert_eq!(exp.kind, kind);
            // regression problems are p = dim (no class flattening)
            assert_eq!(exp.problem.dim(), 6);
            assert!(exp.problem.smoothness().is_finite());
            assert!(exp.problem.strong_convexity() > 0.0);
        }
    }

    #[test]
    fn reference_is_cached_and_injectable() {
        let exp = Experiment::from_config(&tiny("logreg")).unwrap();
        let a = exp.reference();
        let b = exp.reference();
        assert!(Arc::ptr_eq(&a, &b));
        // injection after the fact is a no-op
        exp.set_reference(Arc::new(vec![0.0; exp.problem.dim()]));
        assert!(Arc::ptr_eq(&exp.reference(), &a));
        // injection before first use wins
        let exp2 = Experiment::from_config(&tiny("logreg")).unwrap();
        exp2.set_reference(Arc::clone(&a));
        assert!(Arc::ptr_eq(&exp2.reference(), &a));
    }

    #[test]
    fn run_drives_the_engine() {
        let exp = Experiment::from_config(&tiny("logreg")).unwrap();
        let res = exp.run(&exp.run_spec());
        assert_eq!(res.history.last().unwrap().round, 40);
        assert!(res.final_subopt().is_finite());
        assert!(res.name.starts_with("Prox-LEAD"));
        assert_eq!(res.backend, crate::runner::Backend::Engine);
        assert_eq!(res.stopped_by, crate::runner::StopReason::MaxRounds);
    }

    #[test]
    fn builder_routes_overrides_and_errors() {
        let exp = Experiment::builder()
            .problem("least-squares")
            .nodes(4)
            .set("samples_per_node", "24")
            .set("dim", "6")
            .set("batches", "4")
            .build()
            .unwrap();
        assert_eq!(exp.kind, ProblemKind::LeastSquares);
        assert!(Experiment::builder().set("warp_drive", "on").build().is_err());
        assert!(Experiment::builder().set("problem", "sudoku").build().is_err());
        assert!(Experiment::builder().algorithm("gradient-descent-but-wrong").build().is_err());
    }

    #[test]
    fn validate_config_is_cheap_and_strict() {
        assert!(validate_config(&tiny("logreg")).is_ok());
        assert!(validate_config(&tiny("lasso")).is_ok());
        let mut bad = tiny("logreg");
        bad.algorithm = "nope".into();
        assert!(validate_config(&bad).is_err());
        let mut bad = tiny("logreg");
        bad.samples_per_node = 25; // not divisible into 4 batches
        assert!(validate_config(&bad).is_err());
        let mut bad = tiny("logreg");
        bad.backend = "tpu".into();
        assert!(validate_config(&bad).is_err());
        let mut bad = tiny("logreg");
        bad.compute = "tpu".into();
        assert!(validate_config(&bad).is_err());
    }

    #[test]
    fn custom_problem_injection_checks_node_count() {
        let (shards, _) = crate::problem::data::sparse_regression(4, 24, 8, 3, 0.05, 5);
        let p: Arc<dyn Problem> = Arc::new(crate::problem::LeastSquares::new(shards, 1e-2, 4));
        let ok = ExperimentBuilder::new()
            .nodes(4)
            .set("samples_per_node", "24")
            .with_problem(Arc::clone(&p))
            .build();
        assert!(ok.is_ok());
        assert_eq!(ok.unwrap().problem.dim(), 8);
        let bad = ExperimentBuilder::new().nodes(8).with_problem(p).build();
        assert!(bad.unwrap_err().0.contains("nodes"));
    }
}
