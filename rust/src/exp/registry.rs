//! The problem and algorithm registries — name → construction, in one
//! place each.
//!
//! **Problems** (`problem =` config key, [`ProblemKind`]): `logreg` builds
//! the §5 blob-classification workload (optionally wrapped by the PJRT
//! backend), `least-squares` / `lasso` build quadratic suites from the
//! regression generator (dense vs k-sparse ground truth).
//!
//! **Algorithms** (`algorithm =` config key): every name the sweep grid,
//! the CLI, and the benches accept, dispatching to the typed builders in
//! [`crate::algorithm::builder`] — and, for the message-passing
//! coordinator, to the per-node halves in [`crate::coordinator::algorithms`]
//! via [`build_node_algorithm`]. Per-family parameter conventions:
//!
//! - `prox-lead` / `lead`: (η, α, γ) from the experiment (`lead` forces
//!   r ≡ 0);
//! - `dgd` / `prox-dgd`: η;
//! - `choco`: η with γ as the gossip stepsize γ_c;
//! - `pdgm` / `lessbit-b`: θ = γ/(2η) (the PDHG view), α for COMM;
//! - `dualgd` / `lessbit-a`: dual stepsize θ = η when set explicitly, else
//!   μ/2 (μ/4 when compressed), with a fixed warm-started inner solve.

use super::Experiment;
use crate::algorithm::{
    dualgd_default_theta, pdgm_default_theta, Algorithm, Choco, Dgd, DualGd, Nids, P2d2, Pdgm,
    PgExtra, ProxLead, DUALGD_INNER_ITERS,
};
use crate::config::{Config, ConfigError};
use crate::coordinator::{
    ChocoNode, CoordConfig, DgdNode, DualGdNode, NidsNode, NodeAlgorithm, NodeHyper, P2d2Node,
    PdgmNode, PgExtraNode, ProxLeadNode, WeightRow,
};
use crate::problem::data::{blobs, regression};
use crate::problem::{LeastSquares, LogReg, Problem, ProblemKind};
use crate::prox::{Prox, Zero};
use std::sync::Arc;

/// Canonical algorithm names (aliases: `proxlead`, `prox-dgd`, `pgextra`,
/// `lessbit-a`, `lessbit-b`). The exp-level matrix test iterates this.
pub const ALGORITHM_NAMES: &[&str] =
    &["prox-lead", "lead", "dgd", "choco", "nids", "p2d2", "pg-extra", "pdgm", "dualgd"];

/// Err unless `name` is a run backend (`engine` | `coordinator` | `sim`);
/// the key every [`crate::exp::Experiment::run_backend`] dispatch and the
/// sweep grid validate against.
pub fn ensure_backend(name: &str) -> Result<(), ConfigError> {
    match name {
        "engine" | "coordinator" | "sim" => Ok(()),
        b => Err(ConfigError(format!("unknown backend '{b}' (engine | coordinator | sim)"))),
    }
}

/// Err unless `name` is a registered algorithm (canonical or alias).
pub fn ensure_algorithm(name: &str) -> Result<(), ConfigError> {
    match name {
        "prox-lead" | "proxlead" | "lead" | "dgd" | "prox-dgd" | "choco" | "nids" | "p2d2"
        | "pg-extra" | "pgextra" | "pdgm" | "lessbit-b" | "dualgd" | "lessbit-a" => Ok(()),
        a => Err(ConfigError(format!("unknown algorithm '{a}'"))),
    }
}

/// Shape checks the generators would otherwise `assert!` on: positive
/// node/batch counts and batch-divisible per-node sample counts.
pub fn check_problem_shape(cfg: &Config) -> Result<(), ConfigError> {
    if cfg.nodes == 0 {
        return Err(ConfigError("nodes must be positive".into()));
    }
    if cfg.batches == 0 || cfg.samples_per_node % cfg.batches != 0 {
        return Err(ConfigError(format!(
            "samples_per_node ({}) must split into batches ({}) evenly",
            cfg.samples_per_node, cfg.batches
        )));
    }
    match cfg.compute.as_str() {
        "native" | "xla" => Ok(()),
        c => Err(ConfigError(format!("unknown compute '{c}' (native | xla)"))),
    }
}

/// The problem registry: build the instance a config's `problem` key
/// names. Sweeps and the CLI both construct through here (the PJRT/XLA
/// wrapper is applied when `compute = xla`; logreg only).
pub fn build_problem(cfg: &Config) -> Result<Arc<dyn Problem>, ConfigError> {
    let kind = cfg.problem_kind()?;
    check_problem_shape(cfg)?;
    Ok(match kind {
        ProblemKind::LogReg => {
            let native =
                LogReg::new(blobs(&cfg.blob_spec()), cfg.classes, cfg.lambda2, cfg.batches);
            if cfg.compute == "xla" {
                wrap_xla(cfg, native)?
            } else {
                Arc::new(native)
            }
        }
        ProblemKind::LeastSquares | ProblemKind::Lasso => {
            if cfg.compute == "xla" {
                return Err(ConfigError(
                    "compute = xla supports only problem = logreg (no regression artifacts)"
                        .into(),
                ));
            }
            // lasso: k-sparse ground truth at the canonical p/8 support;
            // least-squares: dense ground truth (ridge suite)
            let sparsity = if kind == ProblemKind::Lasso { (cfg.dim / 8).max(1) } else { 0 };
            let (shards, _x_true) = regression(&cfg.reg_spec(sparsity));
            Arc::new(LeastSquares::new(shards, cfg.lambda2, cfg.batches))
        }
    })
}

/// Wrap a native logreg in the PJRT-backed gradient executor.
fn wrap_xla(cfg: &Config, native: LogReg) -> Result<Arc<dyn Problem>, ConfigError> {
    use crate::runtime::{default_artifact_dir, PjrtRuntime, XlaLogReg};
    let rt = PjrtRuntime::load(&default_artifact_dir()).map_err(|e| {
        ConfigError(format!("compute = xla requested but artifacts unavailable: {e}"))
    })?;
    let xla = XlaLogReg::new(native, Arc::new(rt))
        .map_err(|e| ConfigError(format!("compute = xla: {e}")))?;
    if !xla.batch_on_xla() && cfg.oracle != "full" {
        eprintln!("note: no batch-shape artifact; stochastic draws use the native kernel");
    }
    Ok(Arc::new(xla))
}

/// The one DualGD/LessBit-A θ resolution both registries share: an explicit
/// config η is read as the dual stepsize θ; otherwise the theory default
/// (μ/2, μ/4 when the communication is compressed). A sentinel change here
/// cannot desynchronize the engine and coordinator paths.
fn dualgd_theta(exp: &Experiment, compressed: bool) -> f64 {
    if exp.config.eta > 0.0 {
        exp.config.eta
    } else {
        dualgd_default_theta(exp.problem.strong_convexity(), compressed)
    }
}

/// The algorithm registry: instantiate the algorithm an experiment's
/// config names, over the experiment's resolved components, with an
/// explicit RNG seed.
pub fn build_algorithm(exp: &Experiment, seed: u64) -> Result<Box<dyn Algorithm>, ConfigError> {
    let cfg = &exp.config;
    Ok(match cfg.algorithm.as_str() {
        "prox-lead" | "proxlead" => Box::new(ProxLead::builder(exp).seed(seed).build()),
        "lead" => Box::new(ProxLead::builder(exp).prox(Box::new(Zero)).seed(seed).build()),
        "dgd" | "prox-dgd" => Box::new(Dgd::builder(exp).seed(seed).build()),
        "choco" => Box::new(Choco::builder(exp).seed(seed).build()),
        "nids" => Box::new(Nids::builder(exp).seed(seed).build()),
        "p2d2" => Box::new(P2d2::builder(exp).seed(seed).build()),
        "pg-extra" | "pgextra" => Box::new(PgExtra::builder(exp).seed(seed).build()),
        "pdgm" | "lessbit-b" => Box::new(Pdgm::builder(exp).seed(seed).build()),
        "dualgd" | "lessbit-a" => {
            let theta = dualgd_theta(exp, exp.compressor().variance_bound() > 0.0);
            Box::new(DualGd::builder(exp).theta(theta).seed(seed).build())
        }
        a => return Err(ConfigError(format!("unknown algorithm '{a}'"))),
    })
}

/// The node-side registry: build node `node`'s half of the experiment's
/// configured algorithm for the message-passing coordinator. The same name
/// table and per-family parameter conventions as [`build_algorithm`] —
/// `Experiment::run_coordinator` hands this to `coordinator::run` as the
/// per-node factory, so `train`, sweeps, and the wire-bytes bench accept
/// every `algorithm=` value.
///
/// The engine's "is this run compressed?" rule (the configured compressor's
/// variance bound) maps onto the codec: a lossy wire (`Quant`) switches the
/// dual methods onto their COMM halves (LessBit-A/B) and derives DualGD's
/// θ = μ/4 instead of μ/2, exactly like the builder does for a lossy
/// compressor.
pub fn build_node_algorithm(
    exp: &Experiment,
    wire: &CoordConfig,
    node: usize,
    row: WeightRow,
) -> Box<dyn NodeAlgorithm> {
    debug_assert_eq!(row.node, node, "gossip row must belong to the node being built");
    let p = Arc::clone(&exp.problem);
    let prox: Arc<dyn Prox> = Arc::from(exp.prox());
    let x0 = &exp.x0;
    // the engine's Hyper + oracle, restated per node (η resolved by the
    // experiment; the wire config carries codec/seed)
    let h = &NodeHyper::new(exp.hyper.eta)
        .alpha(exp.config.alpha)
        .gamma(exp.config.gamma)
        .oracle(exp.oracle());
    match exp.config.algorithm.as_str() {
        "prox-lead" | "proxlead" => Box::new(ProxLeadNode::new(p, prox, x0, row, h, wire)),
        "lead" => Box::new(ProxLeadNode::new(p, Arc::new(Zero), x0, row, h, wire)),
        "dgd" | "prox-dgd" => Box::new(DgdNode::new(p, prox, x0, row, h, wire)),
        "choco" => Box::new(ChocoNode::new(p, prox, x0, row, h, wire)),
        "nids" => Box::new(NidsNode::new(p, prox, x0, row, h, wire)),
        "p2d2" => Box::new(P2d2Node::new(p, prox, x0, row, h, wire)),
        "pg-extra" | "pgextra" => Box::new(PgExtraNode::new(p, prox, x0, row, h, wire)),
        "pdgm" | "lessbit-b" => {
            // θ = γ/(2η), the PDHG view — the same helper the PdgmBuilder
            // defaults through
            let theta = pdgm_default_theta(h.eta, h.gamma);
            Box::new(PdgmNode::new(p, x0, row, theta, h, wire))
        }
        "dualgd" | "lessbit-a" => {
            let theta = dualgd_theta(exp, wire.codec.is_lossy());
            Box::new(DualGdNode::new(p, x0, row, theta, DUALGD_INNER_ITERS, h, wire))
        }
        a => unreachable!("algorithm '{a}' validated at Experiment construction"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny(problem: &str) -> Config {
        Config::parse(&format!(
            "problem = {problem}\nnodes = 4\nsamples_per_node = 24\ndim = 6\nclasses = 3\n\
             batches = 4\nlambda2 = 0.1\n"
        ))
        .unwrap()
    }

    #[test]
    fn problem_registry_builds_every_kind() {
        for (name, p_dim) in [("logreg", 18), ("least-squares", 6), ("lasso", 6)] {
            let p = build_problem(&tiny(name)).unwrap();
            assert_eq!(p.num_nodes(), 4, "{name}");
            assert_eq!(p.dim(), p_dim, "{name}");
            assert_eq!(p.num_batches(), 4, "{name}");
        }
    }

    #[test]
    fn lasso_truth_is_sparser_than_least_squares() {
        // the two regression kinds draw different ground truths
        let cfg = tiny("lasso");
        let (_, x_lasso) = regression(&cfg.reg_spec((cfg.dim / 8).max(1)));
        let (_, x_dense) = regression(&cfg.reg_spec(0));
        let nnz = |v: &[f64]| v.iter().filter(|&&x| x != 0.0).count();
        assert_eq!(nnz(&x_lasso), 1); // dim 6 ⇒ support max(6/8, 1) = 1
        assert!(nnz(&x_dense) > 3);
    }

    #[test]
    fn xla_compute_is_logreg_only() {
        let mut cfg = tiny("least-squares");
        cfg.compute = "xla".into();
        assert!(build_problem(&cfg).unwrap_err().0.contains("logreg"));
    }

    #[test]
    fn shape_checks_reject_bad_batching() {
        let mut cfg = tiny("logreg");
        cfg.batches = 5; // 24 % 5 != 0
        assert!(check_problem_shape(&cfg).is_err());
        cfg.batches = 0;
        assert!(check_problem_shape(&cfg).is_err());
        cfg.batches = 4;
        cfg.compute = "quantum".into();
        assert!(check_problem_shape(&cfg).is_err());
    }

    #[test]
    fn every_name_in_the_registry_validates() {
        for name in ALGORITHM_NAMES {
            assert!(ensure_algorithm(name).is_ok(), "{name}");
        }
        for alias in ["proxlead", "prox-dgd", "pgextra", "lessbit-a", "lessbit-b"] {
            assert!(ensure_algorithm(alias).is_ok(), "{alias}");
        }
        assert!(ensure_algorithm("adamw").is_err());
    }
}
