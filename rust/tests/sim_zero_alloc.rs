//! Pins the sim backend's memory claim: after warmup, a simulation round
//! allocates NOTHING — the round loop runs entirely in buffers sized at
//! startup (CSR shards, per-node frames, the shared broadcast matrix,
//! per-participant scratch).
//!
//! A counting `#[global_allocator]` wraps the system allocator (the same
//! harness as `wire_zero_alloc.rs`; one `#[test]` so no parallel test
//! thread allocates into the measured window). Two identical runs that
//! differ only in round count must allocate the *same* number of times:
//! per-run setup (experiment wiring, thread spawns, scratch warmup) is
//! equal by construction, so any difference is a per-round allocation.
//!
//! Documented exclusions, all sized at startup and identical across the
//! two runs, so they cannot hide a per-round allocation: snapshot rows in
//! the preallocated history (`record_every` here samples only round 0 and
//! the final round in both runs) and the per-participant scratch warmup.
//! The problem is least-squares: its `grad_slice` is allocation-free
//! (logreg's allocates a logits buffer per call, which would charge the
//! oracle, not the round loop, to this pin).

use proxlead::config::Config;
use proxlead::exp::{registry, Experiment};
use proxlead::runner::RunSpec;
use proxlead::sim;
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

struct CountingAlloc;

static ALLOCS: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static COUNTER: CountingAlloc = CountingAlloc;

fn allocs() -> usize {
    ALLOCS.load(Ordering::Relaxed)
}

#[test]
fn sim_round_loop_is_zero_alloc_after_warmup() {
    // 2-bit quantized wire: the round loop covers encode → frame → parse →
    // decode → mix → prox step, including the per-node dither RNG draws
    let cfg = Config::parse(
        "problem = least-squares\nalgorithm = prox-lead\nnodes = 64\n\
         samples_per_node = 4\ndim = 6\nbatches = 1\nseed = 9\n\
         lambda1 = 0.005\nlambda2 = 0.1\nbits = 2\n",
    )
    .expect("zero-alloc config");
    let exp = Experiment::from_config(&cfg).expect("experiment");
    // x* = 0 keeps the FISTA reference solve out of the measured window
    exp.set_reference(std::sync::Arc::new(vec![0.0; exp.x0.cols]));
    let x_star = exp.reference();

    // record_every ≫ rounds: both runs snapshot exactly twice (round 0 and
    // the always-sampled final round), so history pushes are equal too
    let run_rounds = |rounds: usize| -> usize {
        let spec = RunSpec::fixed(rounds).every(1_000);
        let wire = exp.coord_config();
        let before = allocs();
        let res = sim::run_with_workers(
            &exp.mixing,
            &exp.x0,
            &exp.config.algorithm,
            &wire,
            &spec,
            &x_star,
            &mut [],
            |i, row| registry::build_node_algorithm(&exp, &wire, i, row),
            2, // fixed pool: identical thread-spawn count in both runs
        );
        let after = allocs();
        assert_eq!(res.history.len(), 2, "round 0 + final round only");
        assert_eq!(res.history.last().unwrap().round, rounds);
        assert!(res.final_subopt().is_finite());
        after - before
    };

    // first run warms lazy process-wide state (thread-local init, condvar
    // internals); then compare best-of-two at each round count
    let _warm = run_rounds(4);
    let short = run_rounds(4).min(run_rounds(4));
    let long = run_rounds(12).min(run_rounds(12));
    assert!(
        long <= short,
        "8 extra warmed-up sim rounds allocated {} time(s) \
         (setup allocs: {short} for 4 rounds, {long} for 12)",
        long - short
    );
}
