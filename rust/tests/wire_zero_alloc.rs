//! Pins the tentpole claim: the wire codec hot path performs ZERO heap
//! allocations per round once its scratch buffers have warmed up.
//!
//! A counting `#[global_allocator]` wraps the system allocator; the single
//! test below (one `#[test]` so no parallel test thread allocates into the
//! measured window) warms each codec's scratch, then drives several full
//! encode → frame → parse → decode → mix rounds and asserts the allocation
//! counter did not move. The one per-round allocation the coordinator
//! still makes — the `Arc<[u8]>` transport buffer the channel handoff
//! needs — lives *outside* these codec paths and is excluded by design
//! (see DESIGN.md §4).

use proxlead::coordinator::wire::{frame_begin, frame_end};
use proxlead::coordinator::{FrameRef, WeightRow, WireCodec};
use proxlead::util::rng::Rng;
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

struct CountingAlloc;

static ALLOCS: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static COUNTER: CountingAlloc = CountingAlloc;

fn allocs() -> usize {
    ALLOCS.load(Ordering::Relaxed)
}

#[test]
fn codec_round_trip_is_zero_alloc_after_warmup() {
    let p = 600usize; // several quant blocks, non-integral byte boundary
    let mut rng = Rng::new(42);
    let x: Vec<f64> = (0..p).map(|_| rng.normal()).collect();
    // a 3-neighbor gossip row exercising mix_into's spliced-diagonal loop
    let row = WeightRow {
        node: 2,
        self_weight: 0.4,
        neighbors: vec![(0, 0.2), (1, 0.2), (5, 0.2)],
    };

    for codec in [WireCodec::Dense64, WireCodec::Dense32, WireCodec::Quant(2, 256)] {
        // scratch allocated once, exactly as run_node does
        let mut frame_buf: Vec<u8> = Vec::new();
        let mut q_own = vec![0.0; p];
        let mut peers: Vec<(usize, Vec<f64>)> =
            row.neighbors.iter().map(|&(j, _)| (j, vec![0.0; p])).collect();
        let mut mixed = vec![0.0; p];

        // warmup round: grows frame_buf to its steady-state capacity
        let mut round = |rng: &mut Rng, frame_buf: &mut Vec<u8>, k: u32| {
            frame_begin(frame_buf, codec.tag(), k, 2);
            let bits = codec.encode_into(&x, rng, &mut q_own, frame_buf);
            frame_end(frame_buf);
            let f = FrameRef::parse(frame_buf).expect("well-formed frame");
            assert_eq!(f.round, k);
            for slot in peers.iter_mut() {
                codec.decode_into(f.payload, &mut slot.1).expect("well-formed payload");
            }
            row.mix_into(&mut mixed, &q_own, &peers);
            bits
        };
        round(&mut rng, &mut frame_buf, 0);

        let before = allocs();
        let mut total_bits = 0u64;
        for k in 1..=8u32 {
            total_bits += round(&mut rng, &mut frame_buf, k);
        }
        let after = allocs();
        assert!(total_bits > 0);
        assert_eq!(
            after - before,
            0,
            "{codec:?}: encode_into/FrameRef::parse/decode_into/mix_into allocated \
             {} time(s) across 8 warmed-up rounds",
            after - before
        );
    }
}
