//! Acceptance tests for the unified run API (ISSUE 5): composable stop
//! criteria honored by BOTH backends through one `RunSpec` → `RunResult`
//! shape, with the coordinator's early stop actually reaching the node
//! threads.
//!
//! 1. **Bits-budget cross-backend parity** — under the exact `Dense64`
//!    codec with `record_every = 1`, a payload-bit budget stops the matrix
//!    engine and the node-thread coordinator on the same round at the same
//!    cumulative bit count, both reporting `stopped_by = BitsBudget`.
//! 2. **Wire-level budget stop** — a 2-bit Prox-LEAD coordinator run (the
//!    paper's wire) stops early at a bit budget: the early-stop broadcast
//!    reaches the node threads, the history is truncated, and the run
//!    reports how it ended.
//! 3. **Target/deadline/grad-evals stops on the coordinator** — the stops
//!    the engine always had now work on node threads.
//! 4. **Streaming probes** — a CSV probe observes every sample of a
//!    coordinator run as it happens.

use proxlead::config::Config;
use proxlead::exp::Experiment;
use proxlead::runner::{Backend, CsvProbe, Probe, RunSpec, StopReason};
use std::time::Duration;

fn base_cfg(bits: u32, rounds: usize, record_every: usize) -> Config {
    Config::parse(&format!(
        "nodes = 4\nsamples_per_node = 24\ndim = 5\nclasses = 3\nbatches = 4\n\
         separation = 1.0\nseed = 33\nlambda1 = 0.005\nlambda2 = 0.1\nbits = {bits}\n\
         rounds = {rounds}\nrecord_every = {record_every}\n"
    ))
    .expect("run_api config")
}

#[test]
fn bits_budget_stops_both_backends_at_the_same_count() {
    // Dense64: engine accounting (Identity::f64) and wire payload agree at
    // 64 bits/entry, so the budget must bite on the same round with the
    // same cumulative count on both backends
    let exp = Experiment::from_config(&base_cfg(64, 200, 1)).unwrap();
    let per_round = (exp.config.nodes * exp.problem.dim() * 64) as u64;
    let spec = exp.run_spec().bits_budget(7 * per_round);

    let engine = exp.run(&spec);
    let coord = exp.run_coordinator(&spec);

    assert_eq!(engine.stopped_by, StopReason::BitsBudget);
    assert_eq!(coord.stopped_by, StopReason::BitsBudget);
    let (e, c) = (engine.history.last().unwrap(), coord.history.last().unwrap());
    assert_eq!(e.round, 7, "engine should stop exactly at the budget");
    assert_eq!(c.round, e.round, "both backends must stop on the same round");
    assert_eq!(c.bits, e.bits, "both backends must stop at the same cumulative bit count");
    assert_eq!(c.bits, 7 * per_round);
    // and the iterates at the stop are bit-identical (Dense64 parity)
    for (a, b) in coord.final_x.data.iter().zip(&engine.final_x.data) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
}

#[test]
fn two_bit_prox_lead_coordinator_stops_at_a_bit_budget() {
    // the acceptance scenario: a communication-budgeted wire experiment.
    // Run once unbounded to learn the full cost, then demand half.
    let exp = Experiment::from_config(&base_cfg(2, 400, 1)).unwrap();
    let full = exp.run_coordinator(&exp.run_spec());
    assert_eq!(full.stopped_by, StopReason::MaxRounds);
    let total_bits = full.history.last().unwrap().bits;

    let budget = total_bits / 2;
    let res = exp.run_coordinator(&exp.run_spec().bits_budget(budget));
    assert_eq!(res.stopped_by, StopReason::BitsBudget, "must report how it ended");
    let last = res.history.last().unwrap();
    assert!(last.round < 400, "early stop must reach the node threads, ran {}", last.round);
    assert!(last.bits >= budget, "stop fires at the first snapshot over budget");
    assert!(
        last.bits < total_bits,
        "budgeted run must move fewer bits than the full run ({} vs {total_bits})",
        last.bits
    );
    assert_eq!(res.backend, Backend::Coordinator);
    assert!(res.wire_bytes() > 0 && res.wire_bytes() < full.wire_bytes());
}

#[test]
fn coordinator_honors_target_subopt() {
    let exp = Experiment::from_config(&base_cfg(2, 3000, 1)).unwrap();
    let res = exp.run_coordinator(&exp.run_spec().until(1e-6));
    assert_eq!(res.stopped_by, StopReason::TargetSubopt);
    let hit = res.rounds_to_target().expect("target reached");
    assert!(hit < 3000, "should early-stop, took {hit}");
    assert!(res.final_subopt() < 1e-6);
}

#[test]
fn coordinator_honors_deadline() {
    // a zero deadline trips at the first gated checkpoint — the broadcast
    // stops all nodes long before the 50k-round cap
    let exp = Experiment::from_config(&base_cfg(2, 50_000, 10)).unwrap();
    let res = exp.run_coordinator(&exp.run_spec().deadline(Duration::ZERO));
    assert_eq!(res.stopped_by, StopReason::Deadline);
    let last = res.history.last().unwrap().round;
    assert_eq!(last, 10, "deadline fires at the first checkpoint (record_every granularity)");
}

#[test]
fn coordinator_honors_grad_evals_budget() {
    let exp = Experiment::from_config(&base_cfg(2, 5000, 5)).unwrap();
    // round-0 init cost (engine ≡ coordinator accounting, pinned by the
    // parity suite) from a 1-round engine run — cheap
    let init = exp.run(&RunSpec::fixed(1)).history[0].grad_evals;
    let res = exp.run_coordinator(&exp.run_spec().grad_evals_budget(init * 3));
    assert_eq!(res.stopped_by, StopReason::GradEvalsBudget);
    let last = res.history.last().unwrap();
    assert!(last.round < 5000, "budget must bite early, ran {}", last.round);
    assert!(last.grad_evals >= init * 3);
}

#[test]
fn stop_granularity_is_record_every_on_the_coordinator() {
    // with record_every = 25 the leader only observes every 25th round, so
    // a budget stop lands on a multiple of 25
    let exp = Experiment::from_config(&base_cfg(2, 400, 25)).unwrap();
    let full = exp.run_coordinator(&exp.run_spec());
    let total_bits = full.history.last().unwrap().bits;
    let res = exp.run_coordinator(&exp.run_spec().bits_budget(total_bits / 3));
    assert_eq!(res.stopped_by, StopReason::BitsBudget);
    let last = res.history.last().unwrap().round;
    assert!(last % 25 == 0 && last < 400, "stop must land on a checkpoint, got {last}");
}

#[test]
fn csv_probe_streams_coordinator_samples() {
    let exp = Experiment::from_config(&base_cfg(2, 60, 20)).unwrap();
    let mut csv = CsvProbe::new(Vec::new());
    {
        let probes: &mut [&mut dyn Probe] = &mut [&mut csv];
        let res = exp.run_coordinator_probed(&exp.run_spec(), probes);
        assert_eq!(res.history.len(), 4); // rounds 0, 20, 40, 60
    }
    let text = String::from_utf8(csv.into_writer()).unwrap();
    let lines: Vec<&str> = text.lines().collect();
    assert_eq!(lines.len(), 5, "header + 4 samples:\n{text}");
    assert_eq!(lines[0], "round,suboptimality,consensus,bits,wire_bytes,grad_evals");
    assert!(lines[1].starts_with("0,"));
    assert!(lines[4].starts_with("60,"));
    // wire bytes column is live (non-zero once frames flow)
    let cols: Vec<&str> = lines[4].split(',').collect();
    assert!(cols[4].parse::<u64>().unwrap() > 0);
}

#[test]
fn unified_results_serialize_the_same_fields_across_backends() {
    // the "one RunResult" contract consumers rely on: same accessor
    // surface, same history schema, backend tag tells them apart
    let exp = Experiment::from_config(&base_cfg(64, 30, 10)).unwrap();
    let spec = exp.run_spec();
    for res in [exp.run(&spec), exp.run_coordinator(&spec)] {
        assert!(res.final_subopt().is_finite());
        assert_eq!(res.history.first().unwrap().round, 0);
        assert_eq!(res.history.last().unwrap().round, 30);
        assert!(res.rounds_to_target().is_none());
        let series = res.series(proxlead::runner::XAxis::Bits);
        assert_eq!(series.len(), res.history.len());
        let line = res.outcome().summary_line();
        assert!(line.contains(res.backend.name()), "{line}");
    }
}
