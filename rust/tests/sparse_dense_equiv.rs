//! The sparse mixing-operator pipeline's acceptance contract: gossiping
//! through the CSR representation produces the **bit-identical** iterate
//! sequence as the dense matrix path — same summation order in the SpMM
//! kernel (see `linalg::sparse`), same compressed bits, same RNG draws —
//! so switching representations is purely a performance decision. All
//! algorithms are built through the Experiment API, with
//! `Experiment::with_mixing` substituting the representation under test.

use proxlead::algorithm::{Algorithm, ProxLead};
use proxlead::config::Config;
use proxlead::exp::Experiment;
use proxlead::graph::{Graph, MixingOp, MixingRule};
use std::sync::Arc;

/// The ring-32 fixture (12 samples/node, d = 6, C = 3, λ₂ = 0.1,
/// λ₁ = 5e-3, 2-bit ∞-norm) as a config — the same problem the historical
/// BlobSpec fixture generated.
fn ring32_config() -> Config {
    Config::parse(
        "nodes = 32\nsamples_per_node = 12\ndim = 6\nclasses = 3\nbatches = 4\n\
         separation = 1.0\nseed = 41\nlambda1 = 0.005\nlambda2 = 0.1\nbits = 2\n",
    )
    .expect("ring32 config")
}

/// The acceptance criterion: ring n=32, Prox-LEAD 2-bit, 200 rounds —
/// dense and sparse paths produce bit-identical iterate sequences.
#[test]
fn prox_lead_2bit_ring32_bit_identical_over_200_rounds() {
    let cfg = ring32_config();
    let g = Graph::ring(32);
    let dense = MixingOp::dense_from(&g, MixingRule::UniformMaxDegree);
    let sparse = MixingOp::sparse_from(&g, MixingRule::UniformMaxDegree);
    assert!(!dense.is_sparse() && sparse.is_sparse());
    // and the auto-selector picks CSR at this density (96/1024)
    assert!(MixingOp::build(&g, MixingRule::UniformMaxDegree).is_sparse());

    let exp_d = Experiment::from_config(&cfg).unwrap().with_mixing(dense);
    let exp_s = Experiment::from_config(&cfg).unwrap().with_mixing(sparse);
    let p = exp_d.problem.as_ref();
    let mut alg_d = ProxLead::builder(&exp_d).seed(7).build();
    let mut alg_s = ProxLead::builder(&exp_s).seed(7).build();
    for round in 0..200 {
        let sd = alg_d.step(p);
        let ss = alg_s.step(exp_s.problem.as_ref());
        assert_eq!(sd.bits, ss.bits, "round {round}: wire bits diverged");
        let (xd, xs) = (alg_d.x(), alg_s.x());
        for (i, (a, b)) in xd.data.iter().zip(&xs.data).enumerate() {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "round {round}, entry {i}: {a:?} (dense) vs {b:?} (sparse)"
            );
        }
        // the compression states must stay in lockstep too (H drives Q)
        for (a, b) in alg_d.h().data.iter().zip(&alg_s.h().data) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }
    assert!(alg_d.bits() > 0);
    // sanity: the run made optimization progress (not a frozen fixture)
    assert!(alg_d.x().is_finite());
    assert!(alg_d.x().norm_sq() > 0.0);
}

/// Same contract across every stepping algorithm the registry knows, on a
/// sparse-eligible ER graph (each algorithm mixes differently: W,
/// W̃ = (I+W)/2, W − I — all three derived operators must agree).
#[test]
fn all_algorithms_bit_identical_on_er_graph() {
    let cfg = Config::parse(
        "nodes = 24\nsamples_per_node = 12\ndim = 6\nclasses = 3\nbatches = 4\n\
         lambda1 = 0.005\nlambda2 = 0.1\ntopology = er\nconnectivity = 0.3\nmixing = metropolis\n",
    )
    .expect("config");
    let base = Experiment::from_config(&cfg).expect("er experiment");
    let rule = cfg.mixing_rule().unwrap();
    let dense = MixingOp::dense_from(&base.graph, rule);
    let sparse = MixingOp::sparse_from(&base.graph, rule);
    for name in proxlead::exp::ALGORITHM_NAMES {
        let mut c = cfg.clone();
        c.algorithm = (*name).into();
        if *name == "choco" {
            c.gamma = 0.2;
        }
        // share base's problem — only the algorithm/mixing vary per arm
        let exp_d = Experiment::from_config_with_problem(&c, Arc::clone(&base.problem))
            .unwrap()
            .with_mixing(dense.clone());
        let exp_s = Experiment::from_config_with_problem(&c, Arc::clone(&base.problem))
            .unwrap()
            .with_mixing(sparse.clone());
        let mut alg_d = exp_d.algorithm_with_seed(3);
        let mut alg_s = exp_s.algorithm_with_seed(3);
        for round in 0..25 {
            alg_d.step(exp_d.problem.as_ref());
            alg_s.step(exp_s.problem.as_ref());
            for (a, b) in alg_d.x().data.iter().zip(&alg_s.x().data) {
                assert_eq!(a.to_bits(), b.to_bits(), "{name} diverged at round {round}");
            }
        }
    }
}

/// Topology × rule sweep of the equivalence at engine granularity: a short
/// quantized run per combination, final iterates compared bitwise.
#[test]
fn equivalence_holds_across_topologies_and_rules() {
    for topo in ["ring", "chain", "grid", "er"] {
        let mut cfg = ring32_config();
        cfg.set("topology", topo).unwrap();
        if topo == "grid" {
            cfg.nodes = 25; // 32 is not a perfect square
        }
        // one resolution per topology; the rule only swaps the mixing op
        let base = Experiment::from_config(&cfg).unwrap();
        for rule in ["uniform", "metropolis", "lazy"] {
            cfg.set("mixing", rule).unwrap();
            let r = cfg.mixing_rule().unwrap();
            let exp_d =
                base.clone().with_mixing(MixingOp::dense_from(&base.graph, r));
            let exp_s =
                base.clone().with_mixing(MixingOp::sparse_from(&base.graph, r));
            let mut alg_d = ProxLead::builder(&exp_d).seed(7).build();
            let mut alg_s = ProxLead::builder(&exp_s).seed(7).build();
            for _ in 0..40 {
                alg_d.step(exp_d.problem.as_ref());
                alg_s.step(exp_s.problem.as_ref());
            }
            for (a, b) in alg_d.x().data.iter().zip(&alg_s.x().data) {
                assert_eq!(a.to_bits(), b.to_bits(), "{topo}/{rule} diverged");
            }
        }
    }
}
