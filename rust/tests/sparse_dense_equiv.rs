//! The sparse mixing-operator pipeline's acceptance contract: gossiping
//! through the CSR representation produces the **bit-identical** iterate
//! sequence as the dense matrix path — same summation order in the SpMM
//! kernel (see `linalg::sparse`), same compressed bits, same RNG draws —
//! so switching representations is purely a performance decision.

use proxlead::algorithm::{Algorithm, Hyper, ProxLead};
use proxlead::compress::InfNormQuantizer;
use proxlead::graph::{Graph, MixingOp, MixingRule, Topology};
use proxlead::linalg::Mat;
use proxlead::oracle::OracleKind;
use proxlead::problem::data::{blobs, BlobSpec};
use proxlead::problem::{LogReg, Problem};
use proxlead::prox::L1;
use proxlead::util::rng::Rng;

fn ring32_logreg() -> LogReg {
    let spec = BlobSpec {
        nodes: 32,
        samples_per_node: 12,
        dim: 6,
        classes: 3,
        separation: 1.0,
        seed: 41,
        ..Default::default()
    };
    LogReg::new(blobs(&spec), 3, 0.1, 4)
}

fn prox_lead_2bit(p: &LogReg, w: &MixingOp, x0: &Mat) -> ProxLead {
    ProxLead::new(
        p,
        w,
        x0,
        Hyper::paper_default(0.5 / p.smoothness()),
        OracleKind::Full,
        Box::new(InfNormQuantizer::new(2, 256)),
        Box::new(L1::new(5e-3)),
        7,
    )
}

/// The acceptance criterion: ring n=32, Prox-LEAD 2-bit, 200 rounds —
/// dense and sparse paths produce bit-identical iterate sequences.
#[test]
fn prox_lead_2bit_ring32_bit_identical_over_200_rounds() {
    let p = ring32_logreg();
    let g = Graph::ring(32);
    let dense = MixingOp::dense_from(&g, MixingRule::UniformMaxDegree);
    let sparse = MixingOp::sparse_from(&g, MixingRule::UniformMaxDegree);
    assert!(!dense.is_sparse() && sparse.is_sparse());
    // and the auto-selector picks CSR at this density (96/1024)
    assert!(MixingOp::build(&g, MixingRule::UniformMaxDegree).is_sparse());

    let x0 = Mat::zeros(32, p.dim());
    let mut alg_d = prox_lead_2bit(&p, &dense, &x0);
    let mut alg_s = prox_lead_2bit(&p, &sparse, &x0);
    for round in 0..200 {
        let sd = alg_d.step(&p);
        let ss = alg_s.step(&p);
        assert_eq!(sd.bits, ss.bits, "round {round}: wire bits diverged");
        let (xd, xs) = (alg_d.x(), alg_s.x());
        for (i, (a, b)) in xd.data.iter().zip(&xs.data).enumerate() {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "round {round}, entry {i}: {a:?} (dense) vs {b:?} (sparse)"
            );
        }
        // the compression states must stay in lockstep too (H drives Q)
        for (a, b) in alg_d.h().data.iter().zip(&alg_s.h().data) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }
    assert!(alg_d.bits() > 0);
    // sanity: the run made optimization progress (not a frozen fixture)
    assert!(alg_d.x().is_finite());
    assert!(alg_d.x().norm_sq() > 0.0);
}

/// Same contract across every stepping algorithm the sweep registry knows,
/// on a sparse-eligible ER graph (each algorithm mixes differently: W,
/// W̃ = (I+W)/2, W − I — all three derived operators must agree).
#[test]
fn all_algorithms_bit_identical_on_er_graph() {
    use proxlead::config::Config;
    use proxlead::sweep::{build_algorithm, cell_eta};
    let cfg = Config::parse(
        "nodes = 24\nsamples_per_node = 12\ndim = 6\nclasses = 3\nbatches = 4\n\
         lambda1 = 0.005\nlambda2 = 0.1\ntopology = er\nconnectivity = 0.3\nmixing = metropolis\n",
    )
    .expect("config");
    let p = proxlead::sweep::build_problem(&cfg);
    let g = cfg.topology().expect("er graph");
    let dense = MixingOp::dense_from(&g, cfg.mixing_rule().unwrap());
    let sparse = MixingOp::sparse_from(&g, cfg.mixing_rule().unwrap());
    let x0 = Mat::zeros(cfg.nodes, p.dim());
    let eta = cell_eta(&cfg, &p);
    for name in ["prox-lead", "lead", "dgd", "choco", "nids", "p2d2", "pg-extra", "pdgm", "dualgd"]
    {
        let mut c = cfg.clone();
        c.algorithm = name.into();
        if name == "choco" {
            c.gamma = 0.2;
        }
        let mut alg_d = build_algorithm(&c, &p, &dense, &x0, eta, 3).unwrap();
        let mut alg_s = build_algorithm(&c, &p, &sparse, &x0, eta, 3).unwrap();
        for round in 0..25 {
            alg_d.step(&p);
            alg_s.step(&p);
            for (a, b) in alg_d.x().data.iter().zip(&alg_s.x().data) {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "{name} diverged at round {round}"
                );
            }
        }
    }
}

/// Topology × rule sweep of the equivalence at engine granularity: a short
/// quantized run per combination, final iterates compared bitwise.
#[test]
fn equivalence_holds_across_topologies_and_rules() {
    let p = ring32_logreg();
    let x0 = Mat::zeros(32, p.dim());
    let mut rng = Rng::new(17);
    for kind in [Topology::Ring, Topology::Chain, Topology::Grid, Topology::ErdosRenyi] {
        let n = 32; // 32 is not a perfect square; grid gets 25 below
        let g = match kind {
            Topology::Grid => Graph::grid(25),
            _ => Graph::build(kind, n, &mut rng),
        };
        let nodes = g.n;
        let spec = BlobSpec {
            nodes,
            samples_per_node: 12,
            dim: 6,
            classes: 3,
            separation: 1.0,
            seed: 41,
            ..Default::default()
        };
        let prob = LogReg::new(blobs(&spec), 3, 0.1, 4);
        let x0k = if nodes == 32 { x0.clone() } else { Mat::zeros(nodes, prob.dim()) };
        for rule in
            [MixingRule::UniformMaxDegree, MixingRule::Metropolis, MixingRule::LazyMetropolis]
        {
            let mut alg_d = prox_lead_2bit(&prob, &MixingOp::dense_from(&g, rule), &x0k);
            let mut alg_s = prox_lead_2bit(&prob, &MixingOp::sparse_from(&g, rule), &x0k);
            for _ in 0..40 {
                alg_d.step(&prob);
                alg_s.step(&prob);
            }
            for (a, b) in alg_d.x().data.iter().zip(&alg_s.x().data) {
                assert_eq!(a.to_bits(), b.to_bits(), "{kind:?}/{rule:?} diverged");
            }
        }
    }
}
