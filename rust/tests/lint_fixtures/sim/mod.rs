//! Fixture: MUST trigger `zero-alloc` exactly once (allocation inside a
//! scoped sim phase body). Never compiled — scanned by lint_contract.rs.

fn phase_a(n: usize) -> Vec<f64> {
    vec![0.0; n]
}

fn not_a_phase(n: usize) -> Vec<f64> {
    // unscoped fn: allocation is fine here
    Vec::with_capacity(n)
}
