//! Fixture: MUST trigger `bad-allow` exactly once (suppression comment
//! with no justification text). Never compiled — scanned by
//! lint_contract.rs.

pub fn quiet(a: &[f64]) -> f64 {
    // lint:allow(total-cmp):
    a.len() as f64
}
