//! Fixture: MUST trigger `total-cmp` exactly once (NaN-panicking float
//! comparison; the rule is repo-wide). Never compiled — scanned by
//! lint_contract.rs.

pub fn sort_scores(v: &mut [f64]) {
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
}
