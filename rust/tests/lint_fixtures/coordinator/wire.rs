//! Fixture: MUST trigger `panic-freedom` exactly once (bare indexing in a
//! scoped wire-path function). Never compiled — scanned by lint_contract.rs.

pub fn parse(buf: &[u8]) -> u8 {
    buf[0]
}

pub fn helper_outside_scope(buf: &[u8]) -> u8 {
    // same construct, unscoped fn name: the rule must NOT fire here
    buf[1]
}
