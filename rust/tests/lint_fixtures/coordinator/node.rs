//! Fixture: MUST be clean — panicking constructs inside `#[cfg(test)]`
//! are exempt even in scoped functions. Never compiled — scanned by
//! lint_contract.rs.

fn absorb(x: Option<u8>) -> u8 {
    x.unwrap_or(0)
}

#[cfg(test)]
mod tests {
    fn absorb(x: Option<u8>) -> u8 {
        x.unwrap()
    }

    #[test]
    fn indexing_in_tests_is_fine() {
        let buf = [1u8, 2];
        assert_eq!(buf[0], 1);
    }
}
