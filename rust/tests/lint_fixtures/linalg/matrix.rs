//! Fixture: MUST trigger `parity-order` exactly once (a float reduction
//! outside the pinned kernels, with no justification comment). Never
//! compiled — scanned by lint_contract.rs.

pub fn rogue_norm_sq(a: &[f64]) -> f64 {
    a.iter().map(|x| x * x).sum()
}
