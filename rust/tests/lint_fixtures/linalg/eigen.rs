//! Fixture: MUST be clean — a justified suppression exempts the reduction
//! on the following line. Never compiled — scanned by lint_contract.rs.

pub fn pinned_sum(a: &[f64]) -> f64 {
    // lint:allow(parity-order): fixture kernel — order pinned by definition
    a.iter().sum()
}
