//! Fixture: MUST trigger `deprecated-api` exactly once (positional
//! constructor outside algorithm/engine). Never compiled — scanned by
//! lint_contract.rs.

pub fn build() -> ProxLead {
    ProxLead::new(0.1)
}
