//! Fixture: MUST trigger `determinism` exactly once (wall-clock read in a
//! parity-critical module). Never compiled — scanned by lint_contract.rs.

use std::time::Instant;

pub fn step() -> u128 {
    let t0 = Instant::now();
    t0.elapsed().as_nanos()
}
