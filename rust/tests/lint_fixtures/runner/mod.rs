//! Fixture: MUST trigger `atomic-ordering` exactly once (a bare memory-
//! order token outside the runtime/sync shim layer, with no justification
//! comment). Never compiled — scanned by lint_contract.rs.

pub fn rogue_claim(counter: &std::sync::atomic::AtomicUsize) -> usize {
    counter.fetch_add(1, std::sync::atomic::Ordering::Relaxed)
}
