//! Fixture: MUST trigger `panic-freedom` once (bare indexing in a scoped
//! control-frame decoder) and `zero-alloc` once (allocation in the scoped
//! socket read path). Never compiled — scanned by lint_contract.rs.

pub fn decode_hello(payload: &[u8]) -> u8 {
    payload[0]
}

pub fn read_frame_into(scratch: &mut Vec<u8>) {
    let tmp = Vec::with_capacity(64);
    scratch.extend_from_slice(&tmp);
}

pub fn outside_scope(payload: &[u8]) -> Vec<u8> {
    // same constructs, unscoped fn: neither rule may fire
    payload.to_vec()
}
