//! Whole-stack integration: launcher-level configuration → coordinator →
//! (optionally) PJRT gradients, plus failure-injection and schedule paths.

#![allow(deprecated)] // the hand-wired runs intentionally pin the run_prox_lead shim

use proxlead::algorithm::solve_reference;
use proxlead::config::Config;
use proxlead::coordinator::{self, CoordConfig, NodeHyper, Straggler, WireCodec};
use proxlead::exp::Experiment;
use proxlead::linalg::Mat;
use proxlead::oracle::OracleKind;
use proxlead::problem::{LogReg, Problem};
use proxlead::runner::RunSpec;
use proxlead::runtime::{default_artifact_dir, PjrtRuntime, XlaLogReg};
use std::sync::Arc;
use std::time::Duration;

/// Resolve an experiment straight from config text — the same single
/// pipeline `proxlead train` takes.
fn from_config(text: &str) -> Experiment {
    Experiment::from_config(&Config::parse(text).expect("config")).expect("experiment")
}

#[test]
fn config_driven_coordinator_run_converges() {
    let exp = from_config(
        "nodes = 4\nsamples_per_node = 24\ndim = 5\nclasses = 3\nbatches = 4\n\
         lambda1 = 0.005\nlambda2 = 0.1\nseparation = 1.0\nbits = 2\nrounds = 3000\n\
         record_every = 1000\n",
    );
    let res = exp.run_coordinator(&exp.run_spec());
    let s = res.final_subopt();
    assert!(s < 1e-11, "config-driven run suboptimality {s}");
    // wire bytes exceed the accounted payload (entropy-coded) bits: each
    // node unicasts to deg = 2 neighbors, frames add 11-byte headers, and
    // the fixed-width codec spends (b+1)/b × the accounted bits — at this
    // tiny dimension (p = 15) headers dominate, so only sanity-bound it
    let last = res.history.last().unwrap();
    let payload_bytes = last.bits as f64 / 8.0;
    assert!(res.wire_bytes() as f64 > payload_bytes);
    assert!((res.wire_bytes() as f64) < payload_bytes * 2.0 * 8.0);
}

#[test]
fn straggler_faults_do_not_change_the_answer() {
    // same seed, with and without stragglers: identical iterates (the
    // barrier absorbs delay; determinism is per-node-RNG driven)
    let exp = from_config(
        "nodes = 4\nsamples_per_node = 24\ndim = 5\nclasses = 3\nbatches = 4\n\
         lambda2 = 0.1\nseparation = 1.0\n",
    );
    let x_star = vec![0.0; exp.problem.dim()];
    let mk = |straggler: Option<Straggler>| {
        let mut wire = CoordConfig::new(WireCodec::Quant(2, 256));
        wire.straggler = straggler;
        coordinator::run_prox_lead(
            Arc::clone(&exp.problem),
            &exp.mixing,
            &exp.x0,
            Arc::new(proxlead::prox::Zero),
            &NodeHyper::new(0.05),
            &wire,
            &RunSpec::fixed(120).every(120),
            &x_star,
        )
    };
    let clean = mk(None);
    let faulty = mk(Some(Straggler { prob: 0.2, delay: Duration::from_micros(200) }));
    let drift = clean.final_x.dist_sq(&faulty.final_x);
    assert!(drift < 1e-24, "stragglers changed the iterates: {drift}");
}

#[test]
fn coordinator_runs_on_pjrt_backend() {
    let dir = default_artifact_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts missing (run `make artifacts`)");
        return;
    }
    if cfg!(not(feature = "xla")) {
        eprintln!("SKIP: built without the `xla` feature");
        return;
    }
    // the shipped (24, 8, 4) artifact shape
    let spec = proxlead::problem::data::BlobSpec {
        nodes: 4,
        samples_per_node: 24,
        dim: 8,
        classes: 4,
        separation: 1.0,
        seed: 5,
        ..Default::default()
    };
    let native = LogReg::new(proxlead::problem::data::blobs(&spec), 4, 0.005, 4);
    let rt = Arc::new(PjrtRuntime::load(&dir).unwrap());
    let p = Arc::new(XlaLogReg::new(native, rt).unwrap());
    let g = proxlead::graph::Graph::ring(4);
    let w = proxlead::graph::MixingOp::build(&g, proxlead::graph::MixingRule::UniformMaxDegree);
    let x_star = solve_reference(p.as_ref(), 5e-3, 60_000, 1e-12);
    let x0 = Mat::zeros(4, p.dim());
    let hyper = NodeHyper::new(0.5 / p.smoothness()).oracle(OracleKind::Full);
    let res = coordinator::run_prox_lead(
        Arc::clone(&p) as Arc<dyn Problem>,
        &w,
        &x0,
        Arc::new(proxlead::prox::L1::new(5e-3)),
        &hyper,
        &CoordConfig::new(WireCodec::Quant(2, 256)),
        &RunSpec::fixed(600).every(200),
        &x_star,
    );
    // λ2 = 5e-3 is pinned by the artifact, so κ_f is large and 600 rounds
    // only buys partial progress — assert steady descent, not tolerance
    let s = res.final_subopt();
    assert!(s.is_finite());
    let first = res.history.first().unwrap().suboptimality;
    assert!(s < 0.5 * first, "PJRT-backed run should at least halve suboptimality: {s}");
}

#[test]
fn theorem7_schedule_through_engine() {
    use proxlead::algorithm::{ProxLead, Schedule};
    use proxlead::linalg::Spectrum;
    use proxlead::runner::run_engine;
    let exp = from_config(
        "nodes = 4\nsamples_per_node = 24\ndim = 5\nclasses = 3\nbatches = 4\n\
         lambda2 = 0.1\nseparation = 1.0\nbits = 2\n",
    );
    let p = exp.problem.as_ref();
    let x_star = solve_reference(p, 0.0, 40_000, 1e-13);
    let spec = Spectrum::of_mixing(&exp.mixing.to_dense());
    let schedule = Schedule::Theorem7 {
        c: 0.2,
        l: p.smoothness(),
        mu: p.strong_convexity(),
        kappa_g: spec.kappa_g(),
        lmax_iw: spec.lam_max,
    };
    let mut alg = ProxLead::builder(&exp)
        .hyper(schedule.hyper_at(0))
        .oracle(OracleKind::Sgd)
        .prox(Box::new(proxlead::prox::Zero))
        .seed(5)
        .build();
    let res = run_engine(
        &mut alg,
        p,
        &x_star,
        &RunSpec::fixed(30_000).every(3000).with_schedule(schedule),
        &mut [],
    );
    // O(1/k): the second half of the trace keeps improving (no plateau)
    let h = &res.history;
    let mid = h[h.len() / 2].suboptimality;
    let end = h.last().unwrap().suboptimality;
    assert!(end < mid * 0.75, "O(1/k) tail should keep descending: {end} vs {mid}");
}
