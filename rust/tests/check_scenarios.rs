//! The `proxlead-check` scenario suite at Quick budget: every named
//! scenario must pass (no races, deadlocks, or stuck executions), stay
//! schedule-invariant, clear the distinct-schedule floor, and round-trip
//! through the `proxlead-check-v1` JSON report. CI runs the same suite at
//! Full budget (≥ 1000 distinct schedules per scenario) as a hard gate via
//! `cargo run --release --bin check`.

use proxlead::check::report_json;
use proxlead::check::scenarios::{run_all, Budget, NAMES};

#[test]
fn quick_budget_scenarios_pass_and_are_schedule_invariant() {
    let reports = run_all(Budget::Quick);
    assert_eq!(reports.len(), NAMES.len());
    for r in &reports {
        assert!(r.findings.is_empty(), "{}: {:?}", r.name, r.findings);
        assert!(r.pass, "{}", r.summary_line());
        assert!(r.schedule_invariant, "{}", r.summary_line());
        assert!(
            r.distinct >= Budget::Quick.min_distinct(),
            "coverage floor missed: {}",
            r.summary_line()
        );
        assert_eq!(r.outcomes.len(), 1, "{}: outcomes {:?}", r.name, r.outcomes);
    }

    let json = report_json(&reports).to_string();
    assert!(json.contains("\"schema\":\"proxlead-check-v1\""), "{json}");
    assert!(json.contains("\"pass\":true"), "{json}");
    for name in NAMES {
        assert!(json.contains(&format!("\"name\":\"{name}\"")), "{json}");
    }
}
