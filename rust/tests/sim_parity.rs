//! Sim ↔ coordinator ↔ matrix-engine parity — the acceptance suite for the
//! event-driven massive-n simulation backend (`proxlead::sim`).
//!
//! 1. **9-way tri-backend bit matrix** — every `algorithm=` value runs on
//!    the sim under the exact `Dense64` codec via
//!    `Experiment::run_sim(&RunSpec)` and must reproduce both the matrix
//!    engine's and the coordinator's suboptimality history, gradient-eval
//!    totals, wire accounting, and final iterates exactly.
//! 2. **Erdős–Rényi topology** — parity is not a ring artifact: the CSR
//!    mixing path matches on an irregular-degree graph too.
//! 3. **Oracle-stream parity** — a stochastic (SAGA) run matches: the sim
//!    forks the same per-node RNG streams as both other backends.
//! 4. **Stop parity** — a bits-budget run stops all three backends on the
//!    same round at the same cumulative bit count (the same snapshot
//!    grid), with identical final iterates.
//! 5. **Pool-size invariance** — `run_with_workers` is bit-identical for 1,
//!    3, and auto workers: shard claiming reorders which thread runs a
//!    node, never the arithmetic or the RNG streams.
//! 6. **Fault injection** — a tampered broadcast tears the run down with
//!    `StopReason::WireFault`; the sim detects at the broadcast site, so
//!    the fault names the *sender* (the coordinator's receivers would).

use proxlead::config::Config;
use proxlead::coordinator::{FrameTamper, TamperKind};
use proxlead::exp::{registry, Experiment, ALGORITHM_NAMES};
use proxlead::runner::{Backend, RunSpec, StopReason};
use proxlead::sim;

fn cfg_for(algorithm: &str, bits: u32) -> Config {
    let mut cfg = Config::parse(&format!(
        "algorithm = {algorithm}\nnodes = 16\nsamples_per_node = 24\ndim = 5\nclasses = 3\n\
         batches = 4\nseparation = 1.0\nseed = 33\nlambda1 = 0.005\nlambda2 = 0.1\n\
         bits = {bits}\nrounds = 40\nrecord_every = 40\n"
    ))
    .expect("parity config");
    if algorithm == "choco" {
        cfg.gamma = 0.2; // gossip stepsize convention
    }
    cfg
}

/// Assert two runs' iterates and recorded metrics are bit-for-bit equal.
fn assert_bit_equal(tag: &str, a: &proxlead::runner::RunResult, b: &proxlead::runner::RunResult) {
    assert_eq!(a.history.len(), b.history.len(), "{tag}: history length");
    for (x, y) in a.history.iter().zip(&b.history) {
        assert_eq!(x.round, y.round, "{tag}");
        assert_eq!(
            x.suboptimality.to_bits(),
            y.suboptimality.to_bits(),
            "{tag}: suboptimality diverged at round {}",
            x.round
        );
        assert_eq!(x.consensus.to_bits(), y.consensus.to_bits(), "{tag}: round {}", x.round);
        assert_eq!(x.grad_evals, y.grad_evals, "{tag}: grad-eval accounting at {}", x.round);
    }
    for (i, (x, y)) in a.final_x.data.iter().zip(&b.final_x.data).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{tag}: final iterate entry {i} ({x:?} vs {y:?})");
    }
}

#[test]
fn all_nine_algorithms_match_both_backends_bit_for_bit() {
    for name in ALGORITHM_NAMES {
        let exp = Experiment::from_config(&cfg_for(name, 64)).unwrap();
        let spec = exp.run_spec().every(10);
        let s = exp.run_sim(&spec);
        let engine = exp.run(&spec);
        let coord = exp.run_coordinator(&spec);

        assert_eq!(s.backend, Backend::Sim, "{name}");
        assert_eq!(s.stopped_by, StopReason::MaxRounds, "{name}");
        assert_eq!(s.history.last().unwrap().round, exp.config.rounds, "{name}");
        assert_bit_equal(&format!("{name} sim≡engine"), &s, &engine);
        assert_bit_equal(&format!("{name} sim≡coordinator"), &s, &coord);
        // both wire backends serialize the same frames to the same
        // neighbors — payload-bit and framed-byte accounting must agree
        // exactly (the engine has no wire; its bit model is compared in
        // coordinator_parity.rs)
        for (x, y) in s.history.iter().zip(&coord.history) {
            assert_eq!(x.bits, y.bits, "{name}: payload bits at round {}", x.round);
            assert_eq!(x.wire_bytes, y.wire_bytes, "{name}: wire bytes at round {}", x.round);
        }
        assert!(s.wire_bytes() > 0, "{name}: no frames on the sim wire");
    }
}

#[test]
fn erdos_renyi_topology_matches_engine() {
    // irregular degrees, CSR-auto mixing: parity is not a ring artifact
    let mut cfg = cfg_for("prox-lead", 64);
    cfg.nodes = 32;
    cfg.set("topology", "er").unwrap();
    let exp = Experiment::from_config(&cfg).unwrap();
    let spec = exp.run_spec().every(20);
    let s = exp.run_sim(&spec);
    let engine = exp.run(&spec);
    assert_bit_equal("er-32 sim≡engine", &s, &engine);
}

#[test]
fn saga_oracle_streams_match_across_backends() {
    // stochastic draws, not just deterministic gradients: the sim forks
    // Rng::new(seed).fork(i) per node exactly like the node threads do
    let mut cfg = cfg_for("prox-lead", 64);
    cfg.oracle = "saga".into();
    let exp = Experiment::from_config(&cfg).unwrap();
    let spec = exp.run_spec();
    let s = exp.run_sim(&spec);
    let engine = exp.run(&spec);
    let coord = exp.run_coordinator(&spec);
    assert_bit_equal("saga sim≡engine", &s, &engine);
    // per-node SAGA table init (m per node) is counted on all three sides
    assert_eq!(
        s.history.last().unwrap().grad_evals,
        coord.history.last().unwrap().grad_evals
    );
}

#[test]
fn bits_budget_stops_all_three_backends_on_the_same_round() {
    // same snapshot grid ⇒ same stop round at the same cumulative bits
    let mut cfg = cfg_for("prox-lead", 64);
    cfg.rounds = 12;
    cfg.record_every = 1;
    let exp = Experiment::from_config(&cfg).unwrap();
    // the budget that is first met exactly at round 7 (bits are strictly
    // increasing round over round — every round transmits)
    let full = exp.run(&exp.run_spec());
    let budget = full.history.iter().find(|m| m.round == 7).unwrap().bits;
    let spec = exp.run_spec().bits_budget(budget);

    let s = exp.run_sim(&spec);
    let engine = exp.run(&spec);
    let coord = exp.run_coordinator(&spec);
    for (r, tag) in [(&s, "sim"), (&engine, "engine"), (&coord, "coordinator")] {
        assert_eq!(r.stopped_by, StopReason::BitsBudget, "{tag}");
        let end = r.history.last().unwrap();
        assert_eq!(end.round, 7, "{tag}: stop round");
        assert_eq!(end.bits, budget, "{tag}: stop bit count");
    }
    assert_bit_equal("bits-budget sim≡engine", &s, &engine);
    assert_bit_equal("bits-budget sim≡coordinator", &s, &coord);
}

#[test]
fn worker_count_never_changes_results() {
    // the quantized codec exercises the per-node dither RNG streams; any
    // pool size must replay them identically (shard claiming reorders
    // *which thread* runs a node, never the node's arithmetic)
    let cfg = cfg_for("prox-lead", 2);
    let exp = Experiment::from_config(&cfg).unwrap();
    let spec = exp.run_spec().every(10);
    let wire = exp.coord_config();
    let x_star = exp.reference();
    let mut with_pool = |workers: usize| {
        sim::run_with_workers(
            &exp.mixing,
            &exp.x0,
            &exp.config.algorithm,
            &wire,
            &spec,
            &x_star,
            &mut [],
            |i, row| registry::build_node_algorithm(&exp, &wire, i, row),
            workers,
        )
    };
    let auto = exp.run_sim(&spec); // 0 = one worker per core
    let one = with_pool(1);
    let three = with_pool(3);
    assert_bit_equal("1 worker ≡ auto pool", &one, &auto);
    assert_bit_equal("3 workers ≡ auto pool", &three, &auto);
    for m in &auto.history {
        assert_eq!(m.bits, one.history.iter().find(|x| x.round == m.round).unwrap().bits);
    }
}

#[test]
fn tampered_broadcast_faults_at_the_sender() {
    let exp = Experiment::from_config(&cfg_for("prox-lead", 2)).unwrap();
    let x_star = exp.reference();
    let tampered = |round: usize| {
        // cfg bits=2 ⇒ coord_config frames a quantized wire
        let wire = exp
            .coord_config()
            .tamper(FrameTamper { node: 2, round, kind: TamperKind::TruncateHeader });
        sim::run(
            &exp.mixing,
            &exp.x0,
            &exp.config.algorithm,
            &wire,
            &RunSpec::fixed(8).every(2),
            &x_star,
            &mut [],
            |i, row| registry::build_node_algorithm(&exp, &wire, i, row),
        )
    };
    let res = tampered(3);
    match res.stopped_by {
        StopReason::WireFault(f) => {
            // the sim applies the tamper at the broadcast site, so the
            // fault names the *sender* — on the coordinator a receiving
            // neighbor detects it instead (wire_errors.rs)
            assert_eq!(f.node, 2, "sim faults name the tampering sender");
            assert_eq!(f.round, 3, "detected in the tampered round");
        }
        other => panic!("expected StopReason::WireFault, got {other:?}"),
    }
    // the faulted round is discarded; the pre-fault history survives
    let last = res.history.last().unwrap();
    assert!(last.round < 3, "faulted round must not be snapshotted");
    assert_eq!(res.history.first().unwrap().round, 0);
    assert_eq!(res.final_x.rows, exp.x0.rows);

    // a round-0 fault still yields a round-0 history (synthesized from X⁰)
    let res = tampered(0);
    assert!(matches!(res.stopped_by, StopReason::WireFault(_)));
    let first = res.history.first().unwrap();
    assert_eq!(first.round, 0, "round-0 snapshot survives an immediate fault");
    assert!(first.suboptimality.is_finite());
}
