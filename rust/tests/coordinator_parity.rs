//! Coordinator ↔ matrix-engine parity for the whole algorithm registry,
//! through the unified run API — the acceptance suite for the
//! algorithm-generic distributed runtime.
//!
//! 1. **9-way bit-for-bit matrix** — every `algorithm=` value runs on the
//!    message-passing coordinator under the exact `Dense64` codec via
//!    `Experiment::run_coordinator(&RunSpec)` and must reproduce the
//!    matrix engine's `Experiment::run(&RunSpec)` suboptimality history,
//!    gradient-eval totals, and final iterates exactly — the same
//!    `RunResult` shape on both sides.
//! 2. **Oracle-stream parity** — a stochastic (SAGA) run matches too: node
//!    threads draw the engine's per-node oracle streams.
//! 3. **Quantized-wire convergence** — the difference-compressed family
//!    (Prox-LEAD, LEAD, Choco, LessBit-A/B) descends through the real
//!    2-bit framed codec.
//! 4. **Straggler injection on a non-Prox-LEAD algorithm** — delays change
//!    wall-clock only, never the iterates.

use proxlead::config::Config;
use proxlead::exp::{Experiment, ALGORITHM_NAMES};
use proxlead::linalg::Mat;
use proxlead::runner::{Backend, StopReason};

fn cfg_for(algorithm: &str, bits: u32) -> Config {
    let mut cfg = Config::parse(&format!(
        "algorithm = {algorithm}\nnodes = 4\nsamples_per_node = 24\ndim = 5\nclasses = 3\n\
         batches = 4\nseparation = 1.0\nseed = 33\nlambda1 = 0.005\nlambda2 = 0.1\n\
         bits = {bits}\nrounds = 40\nrecord_every = 40\n"
    ))
    .expect("parity config");
    if algorithm == "choco" {
        cfg.gamma = 0.2; // gossip stepsize convention
    }
    cfg
}

/// Suboptimality of the all-zeros start iterate — the descent baseline.
fn zero_subopt(exp: &Experiment, x_star: &[f64]) -> f64 {
    proxlead::algorithm::suboptimality(&Mat::zeros(exp.config.nodes, x_star.len()), x_star)
}

#[test]
fn all_nine_algorithms_match_matrix_engine_bit_for_bit() {
    for name in ALGORITHM_NAMES {
        let exp = Experiment::from_config(&cfg_for(name, 64)).unwrap();
        let spec = exp.run_spec().every(10);
        let coord = exp.run_coordinator(&spec);
        let engine = exp.run(&spec);

        assert_eq!(coord.backend, Backend::Coordinator, "{name}");
        assert_eq!(engine.backend, Backend::Engine, "{name}");
        assert_eq!(coord.stopped_by, StopReason::MaxRounds, "{name}");
        // the unified histories align round for round — including the
        // round-0 post-init sample — and the suboptimality samples are
        // bit-identical under the exact codec
        assert_eq!(coord.history.len(), engine.history.len(), "{name}");
        for (c, e) in coord.history.iter().zip(&engine.history) {
            assert_eq!(c.round, e.round, "{name}");
            assert_eq!(
                c.suboptimality.to_bits(),
                e.suboptimality.to_bits(),
                "{name}: suboptimality diverged at round {}",
                c.round
            );
            assert_eq!(c.consensus.to_bits(), e.consensus.to_bits(), "{name}");
            assert_eq!(c.grad_evals, e.grad_evals, "{name}: grad-eval accounting diverged");
            // bits parity — the counter the bits-budget stop consumes —
            // holds wherever the engine accounts through the configured
            // compressor (64 bits/entry under Identity::f64, matching the
            // Dense64 wire). The nids/pg-extra/p2d2/dual baselines are
            // deliberately excluded: the engine charges them the paper's
            // fixed 32-bit label (and models P2D2's setup exchange as
            // free), which is exactly the model-vs-wire gap the
            // wire_bytes bench measures.
            if matches!(*name, "prox-lead" | "lead" | "dgd" | "choco") {
                assert_eq!(c.bits, e.bits, "{name}: bits accounting diverged at {}", c.round);
            }
        }
        assert_eq!(coord.history.last().unwrap().round, exp.config.rounds, "{name}");
        for (i, (a, b)) in coord.final_x.data.iter().zip(&engine.final_x.data).enumerate() {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "{name}: entry {i} diverged ({a:?} coordinator vs {b:?} engine)"
            );
        }
        assert!(coord.wire_bytes() > 0, "{name}: no frames on the wire");
        assert_eq!(engine.wire_bytes(), 0, "{name}: the engine has no wire");
    }
}

#[test]
fn saga_oracle_streams_match_engine_bit_for_bit() {
    // stochastic draws, not just deterministic gradients: Sgo::for_node
    // aligns each node thread with the engine's per-node RNG fork, so even
    // a SAGA run is bit-identical on the exact codec
    let mut cfg = cfg_for("prox-lead", 64);
    cfg.oracle = "saga".into();
    let exp = Experiment::from_config(&cfg).unwrap();
    let spec = exp.run_spec();
    let coord = exp.run_coordinator(&spec);
    let engine = exp.run(&spec);
    for (i, (a, b)) in coord.final_x.data.iter().zip(&engine.final_x.data).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "saga entry {i}");
    }
    // per-node SAGA table init (m per node) is counted on both sides
    assert_eq!(
        coord.history.last().unwrap().grad_evals,
        engine.history.last().unwrap().grad_evals
    );
}

#[test]
fn compressed_family_descends_on_the_quantized_wire() {
    // the paper's wire: 2-bit ∞-norm frames. Every difference-compressed
    // algorithm (COMM-style state on both endpoints) must make real
    // progress through the actual codec, not just the engine's bit model.
    // (λ1 = 0: the dual family solves the smooth problem.)
    let variants: &[(&str, &[(&str, &str)])] = &[
        ("prox-lead", &[]),
        ("lead", &[]),
        ("choco", &[("gamma", "0.2"), ("eta", "0.05")]),
        ("pdgm", &[("gamma", "0.1"), ("alpha", "0.25")]),
        ("dualgd", &[("alpha", "0.25")]),
    ];
    for &(name, overrides) in variants {
        let mut cfg = cfg_for(name, 2);
        cfg.lambda1 = 0.0;
        cfg.rounds = 800;
        cfg.record_every = 200;
        for &(k, v) in overrides {
            cfg.set(k, v).unwrap();
        }
        let exp = Experiment::from_config(&cfg).unwrap();
        let res = exp.run_coordinator(&exp.run_spec());
        let x_star = exp.reference();
        let s0 = zero_subopt(&exp, &x_star);
        let s = res.final_subopt();
        assert!(s.is_finite(), "{name}: diverged on the quantized wire");
        assert!(s < 0.5 * s0, "{name}: no descent through the 2-bit codec: {s} vs {s0}");
        if name == "prox-lead" || name == "lead" {
            assert!(s < 1e-2 * s0, "{name}: LEAD-family should be deep into descent: {s}");
        }
        assert!(res.wire_bytes() > 0);
    }
}

#[test]
fn straggler_injection_on_nids_changes_nothing_but_wall_clock() {
    // fault injection on a non-Prox-LEAD node half: the synchronous-round
    // barrier absorbs delay, so a straggler-ridden NIDS run is
    // bit-identical to the clean one
    let mk = |straggler: bool| {
        let mut cfg = cfg_for("nids", 64);
        cfg.rounds = 80;
        cfg.record_every = 40;
        if straggler {
            cfg.straggler_prob = 0.15;
            cfg.straggler_us = 200;
        }
        let exp = Experiment::from_config(&cfg).unwrap();
        exp.run_coordinator(&exp.run_spec())
    };
    let clean = mk(false);
    let faulty = mk(true);
    assert_eq!(clean.history.len(), faulty.history.len());
    for (c, f) in clean.history.iter().zip(&faulty.history) {
        assert_eq!((c.round, c.bits, c.grad_evals), (f.round, f.bits, f.grad_evals));
        assert_eq!(c.wire_bytes, f.wire_bytes);
        assert_eq!(
            c.suboptimality.to_bits(),
            f.suboptimality.to_bits(),
            "stragglers changed the iterates"
        );
    }
    for (a, b) in clean.final_x.data.iter().zip(&faulty.final_x.data) {
        assert_eq!(a.to_bits(), b.to_bits(), "stragglers changed the iterates");
    }
}
