//! The linter's own contract: the fixture corpus trips every rule at the
//! expected `file:line`, the real tree is lint-clean, and the wire decode
//! path carries no suppressions at all (ISSUE-8 acceptance criteria).

use std::path::Path;

use proxlead::lint;

fn fixtures_root() -> &'static Path {
    Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/tests/lint_fixtures"))
}

fn src_root() -> &'static Path {
    Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/src"))
}

#[test]
fn fixture_corpus_triggers_every_rule_exactly_once() {
    let (files, diags) = lint::lint_tree(fixtures_root()).expect("fixture scan");
    assert_eq!(files, 11, "fixture corpus drifted: {files} files");
    let got: Vec<(String, usize, &str)> =
        diags.iter().map(|d| (d.file.clone(), d.line, d.rule)).collect();
    let want = [
        ("algorithm/choco.rs".to_string(), 7, "determinism"),
        ("coordinator/wire.rs".to_string(), 5, "panic-freedom"),
        ("exp/registry.rs".to_string(), 6, "deprecated-api"),
        ("linalg/matrix.rs".to_string(), 6, "parity-order"),
        ("runner/mod.rs".to_string(), 6, "atomic-ordering"),
        ("sim/mod.rs".to_string(), 5, "zero-alloc"),
        ("sweep/mod.rs".to_string(), 6, "total-cmp"),
        ("transport/framing.rs".to_string(), 6, "panic-freedom"),
        ("transport/framing.rs".to_string(), 10, "zero-alloc"),
        ("util/bad_allow.rs".to_string(), 6, "bad-allow"),
    ];
    assert_eq!(got, want, "fixture diagnostics drifted");
    // ...which is every rule-id, each exactly once
    let mut ids: Vec<&str> = diags.iter().map(|d| d.rule).collect();
    ids.sort_unstable();
    ids.dedup();
    let mut all = lint::rule_ids();
    all.sort_unstable();
    assert_eq!(ids, all, "some rule has no fixture trigger");
}

#[test]
fn fixture_diagnostics_render_file_line_rule() {
    let (_, diags) = lint::lint_tree(fixtures_root()).expect("fixture scan");
    let shown: Vec<String> = diags.iter().map(|d| d.to_string()).collect();
    assert!(
        shown.iter().any(|s| s.starts_with("coordinator/wire.rs:5: panic-freedom: ")),
        "diagnostic format drifted: {shown:?}"
    );
}

#[test]
fn real_tree_is_lint_clean() {
    let (files, diags) = lint::lint_tree(src_root()).expect("src scan");
    assert!(files >= 50, "src walk looks wrong: only {files} files");
    let listing: Vec<String> = diags.iter().map(|d| d.to_string()).collect();
    assert!(diags.is_empty(), "rust/src must be lint-clean:\n{}", listing.join("\n"));
}

#[test]
fn unjustified_allow_is_rejected_not_honored() {
    // the bad-allow fixture also proves the suppression did NOT take
    // effect — here on a minimal inline source instead of the corpus
    let marker = concat!("// lint:", "allow(");
    let src = format!("fn f(v: &mut [f64]) {{\n    {marker}total-cmp):\n    \
                       v.sort_by(|a, b| a.partial_cmp(b).unwrap());\n}}\n");
    let diags = lint::lint_source("sweep/mod.rs", &src);
    let ids: Vec<&str> = diags.iter().map(|d| d.rule).collect();
    assert!(ids.contains(&"bad-allow"), "{diags:?}");
    assert!(ids.contains(&"total-cmp"), "unjustified allow must not suppress: {diags:?}");
}

#[test]
fn wire_decode_path_has_no_suppressions() {
    // acceptance criterion: panic-freedom in the wire path is enforced by
    // the rule itself, never waived by lint:allow comments
    let marker = concat!("lint:", "allow(");
    for rel in [
        "coordinator/wire.rs",
        "coordinator/node.rs",
        "compress/bits.rs",
        "transport/framing.rs",
    ] {
        let path = src_root().join(rel);
        let src = std::fs::read_to_string(&path).expect("wire-path source readable");
        assert!(
            !src.contains(marker),
            "{rel} must carry no lint suppressions at all (wire decode path)"
        );
    }
}

#[test]
fn json_report_round_trips_diagnostic_fields() {
    let (files, diags) = lint::lint_tree(fixtures_root()).expect("fixture scan");
    let report = lint::report_json(files, &diags).to_string();
    for needle in
        ["\"schema\":\"proxlead-lint-v1\"", "\"clean\":false", "panic-freedom", "bad-allow"]
    {
        assert!(report.contains(needle), "JSON report missing {needle}: {report}");
    }
}
