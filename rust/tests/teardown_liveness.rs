//! Teardown liveness under a fault/finish race: a frame corrupted at the
//! FINAL wire round makes the ABORT teardown race the clean BYE flood (on
//! the coordinator) and the natural end of the round loop (on the sim).
//! Both backends must come to rest within a bounded wall-clock budget —
//! no thread may block on a channel or barrier whose peer already left —
//! and must resolve the reported fault to the min-(round, node) winner.

use std::sync::mpsc;
use std::thread;
use std::time::Duration;

use proxlead::config::Config;
use proxlead::coordinator::{self, FrameTamper, TamperKind};
use proxlead::exp::{registry, Experiment};
use proxlead::runner::StopReason;
use proxlead::sim;

fn ring_exp(nodes: usize, rounds: usize) -> Experiment {
    let cfg = Config::parse(&format!(
        "algorithm = prox-lead\ntopology = ring\nnodes = {nodes}\nsamples_per_node = 6\n\
         dim = 2\nclasses = 2\nbatches = 2\nseed = 11\nlambda1 = 0.005\nlambda2 = 0.1\n\
         bits = 64\nrounds = {rounds}\nrecord_every = 1\n"
    ))
    .expect("config parses");
    Experiment::from_config(&cfg).expect("experiment resolves")
}

/// Run `f` on a worker thread; fail the test if it has not finished
/// within `secs` (a hung teardown shows up as a timeout, not a CI hang).
fn with_watchdog<T: Send + 'static>(secs: u64, f: impl FnOnce() -> T + Send + 'static) -> T {
    let (tx, rx) = mpsc::channel();
    let h = thread::spawn(move || {
        let _ = tx.send(f());
    });
    match rx.recv_timeout(Duration::from_secs(secs)) {
        Ok(v) => {
            h.join().expect("watchdog worker panicked");
            v
        }
        Err(_) => panic!("teardown did not complete within {secs}s — liveness regression"),
    }
}

/// Node 1 corrupts its round-1 (final-round) broadcast in a 3-ring: both
/// neighbors detect and flood ABORT while node 1, whose own gather sees
/// only good frames, finishes cleanly and floods BYE. The leader must
/// resolve the two detector reports to the lowest-(round, node) one.
#[test]
fn coordinator_fault_vs_clean_bye_resolves_min_round_node() {
    let exp = ring_exp(3, 2);
    let wire = exp
        .coord_config()
        .tamper(FrameTamper { node: 1, round: 1, kind: TamperKind::ShortPayload });
    let spec = exp.run_spec();
    let x_star = exp.reference();
    let res = with_watchdog(60, move || {
        coordinator::run(
            &exp.mixing,
            &exp.x0,
            &exp.config.algorithm,
            &wire,
            &spec,
            &x_star,
            &mut [],
            |i, row| registry::build_node_algorithm(&exp, &wire, i, row),
        )
    });
    match res.stopped_by {
        StopReason::WireFault(f) => assert_eq!(
            (f.round, f.node),
            (1u32, 0u16),
            "coordinator must report the lowest-(round, node) *detector*"
        ),
        other => panic!("expected a wire-fault stop, got {other:?}"),
    }
    assert_eq!(res.history.len(), 2, "rounds 0 and 1 flush; the faulted round must not");
}

/// The sim analog: node 2's encoded frame is corrupted at the final wire
/// round, so the participant that claims its shard faults while every
/// other shard completes the round cleanly. The sim reports the *sender*
/// of the corrupt frame, at the faulted round.
#[test]
fn sim_fault_vs_clean_finish_resolves_min_round_node() {
    let exp = ring_exp(4, 2);
    let wire = exp
        .coord_config()
        .tamper(FrameTamper { node: 2, round: 1, kind: TamperKind::TrailingGarbage });
    let spec = exp.run_spec();
    let x_star = exp.reference();
    let res = with_watchdog(60, move || {
        sim::run_with_workers(
            &exp.mixing,
            &exp.x0,
            &exp.config.algorithm,
            &wire,
            &spec,
            &x_star,
            &mut [],
            |i, row| registry::build_node_algorithm(&exp, &wire, i, row),
            2,
        )
    });
    match res.stopped_by {
        StopReason::WireFault(f) => assert_eq!(
            (f.round, f.node),
            (1u32, 2u16),
            "sim must report the *sender* of the corrupt frame"
        ),
        other => panic!("expected a wire-fault stop, got {other:?}"),
    }
    assert_eq!(res.history.len(), 2, "the faulted round's snapshot must not be recorded");
}
