//! The sweep runtime's determinism contract, end-to-end:
//!
//! 1. the same grid run with `threads = 1` and `threads = 8` aggregates
//!    to **byte-identical** JSON (the acceptance criterion — wall-clock
//!    and thread count are deliberately excluded from the aggregate);
//! 2. one sweep cell's trajectory is **bit-identical** to a hand-rolled
//!    serial `runner::run_engine` of the same configuration (the sweep is
//!    the serial path, fanned out — never a different code path).

use proxlead::algorithm::solve_reference;
use proxlead::config::Config;
use proxlead::exp::Experiment;
use proxlead::runner::{run_engine, RunSpec};
use proxlead::sweep::{cell_seed, run_cell, run_sweep, SweepSpec, REF_MAX_ITER, REF_TOL};

fn tiny_base(rounds: usize) -> Config {
    Config::parse(&format!(
        "nodes = 4\nsamples_per_node = 24\ndim = 5\nclasses = 3\nbatches = 4\n\
         lambda1 = 0.005\nlambda2 = 0.1\nrounds = {rounds}\nrecord_every = 25\n"
    ))
    .expect("tiny base config")
}

/// The acceptance grid: ≥ 2 algorithms × ≥ 2 codecs × ≥ 2 seeds, run wide
/// and serial — identical bytes out.
#[test]
fn threads_1_and_8_yield_byte_identical_json() {
    let spec = SweepSpec::new(tiny_base(150))
        .variant(&[("algorithm", "prox-lead")])
        .variant(&[("algorithm", "dgd")])
        .axis("bits", &["2", "32"])
        .axis("seed", &["1", "2"]);
    assert_eq!(spec.num_cells(), 8);
    let serial = run_sweep(&spec.clone().threads(1), |_| {}).expect("serial sweep");
    let wide = run_sweep(&spec.threads(8), |_| {}).expect("wide sweep");
    let a = serial.to_json().to_string();
    let b = wide.to_json().to_string();
    assert_eq!(a, b, "sweep JSON must not depend on thread count");
    // and the underlying traces are bitwise equal, cell by cell
    assert_eq!(serial.cells.len(), 8);
    for (s, w) in serial.cells.iter().zip(&wide.cells) {
        assert_eq!(s.index, w.index);
        assert_eq!(s.seed, w.seed);
        assert_eq!(s.result.history.len(), w.result.history.len());
        for (ms, mw) in s.result.history.iter().zip(&w.result.history) {
            assert_eq!(ms.bits, mw.bits);
            assert_eq!(ms.grad_evals, mw.grad_evals);
            assert_eq!(ms.suboptimality.to_bits(), mw.suboptimality.to_bits());
        }
        assert_eq!(s.result.final_x.data, w.result.final_x.data);
    }
}

/// Repeated runs of the same spec are reproducible (same process, fresh
/// caches) — nothing leaks between sweeps.
#[test]
fn repeated_sweeps_are_reproducible() {
    let spec = SweepSpec::new(tiny_base(80))
        .variant(&[("algorithm", "nids")])
        .variant(&[("algorithm", "prox-lead"), ("bits", "2")])
        .threads(4);
    let a = run_sweep(&spec, |_| {}).expect("first run").to_json().to_string();
    let b = run_sweep(&spec, |_| {}).expect("second run").to_json().to_string();
    assert_eq!(a, b);
}

/// One sweep cell pinned to the serial engine path: same problem, same
/// derived seed, same reference ⇒ the identical MetricPoint sequence and
/// final iterate, bit for bit.
#[test]
fn sweep_cell_matches_serial_engine_run() {
    let spec = SweepSpec::new(tiny_base(200))
        .variant(&[("algorithm", "prox-lead"), ("bits", "2")])
        .axis("seed", &["7"]);
    let cells = spec.cells().expect("cells");
    assert_eq!(cells.len(), 1);
    let outcome = run_cell(&cells[0], None);

    // hand-rolled serial path through runner::run_engine, from the same
    // config
    let cfg = &cells[0].config;
    let exp = Experiment::from_config(cfg).expect("experiment");
    let x_star = solve_reference(exp.problem.as_ref(), cfg.lambda1, REF_MAX_ITER, REF_TOL);
    let seed = cell_seed(cfg.seed, cells[0].index);
    let mut alg = exp.algorithm_with_seed(seed);
    let res = run_engine(
        alg.as_mut(),
        exp.problem.as_ref(),
        &x_star,
        &RunSpec::fixed(cfg.rounds).every(cfg.record_every),
        &mut [],
    );

    assert_eq!(outcome.seed, seed);
    assert_eq!(outcome.result.name, res.name);
    assert_eq!(outcome.result.history.len(), res.history.len());
    for (a, b) in outcome.result.history.iter().zip(&res.history) {
        assert_eq!(a.round, b.round);
        assert_eq!(a.bits, b.bits);
        assert_eq!(a.grad_evals, b.grad_evals);
        assert_eq!(a.suboptimality.to_bits(), b.suboptimality.to_bits());
        assert_eq!(a.consensus.to_bits(), b.consensus.to_bits());
    }
    assert_eq!(outcome.result.final_x.data, res.final_x.data);
    // and the cell actually made progress (this is a real run, not a stub)
    assert!(outcome.final_subopt().is_finite());
    assert!(outcome.final_subopt() < outcome.result.history[0].suboptimality);
}

/// Early-stop targets flow through to `rounds_to_target` and stay
/// deterministic across thread counts.
#[test]
fn target_early_stop_is_deterministic() {
    let spec = SweepSpec::new(tiny_base(6000))
        .variant(&[("algorithm", "prox-lead"), ("bits", "2")])
        .variant(&[("algorithm", "nids"), ("bits", "32")])
        .until(1e-6);
    let serial = run_sweep(&spec.clone().threads(1), |_| {}).expect("serial");
    let wide = run_sweep(&spec.threads(8), |_| {}).expect("wide");
    for (s, w) in serial.cells.iter().zip(&wide.cells) {
        assert_eq!(s.result.rounds_to_target(), w.result.rounds_to_target());
        assert!(
            s.result.rounds_to_target().is_some(),
            "{} should hit 1e-6 within budget",
            s.name
        );
    }
    assert_eq!(serial.to_json().to_string(), wide.to_json().to_string());
}
