//! Acceptance tests for the one-experiment API:
//!
//! 1. **Matrix test** — every registered algorithm × every registered
//!    problem constructs through `Experiment` and completes 50 rounds with
//!    finite iterates (new scenarios are an axis, not a rewrite);
//! 2. **Pin test** — an `Experiment`-built Prox-LEAD reproduces the
//!    pre-refactor constructor-built iterate sequence **bit for bit** on
//!    the ring-32 fixture (resolution moved, arithmetic did not);
//! 3. The `problem` key flows end to end through a sweep grid.

#![allow(deprecated)] // the pin test intentionally uses the legacy constructor

use proxlead::algorithm::{Algorithm, Hyper, ProxLead};
use proxlead::compress::InfNormQuantizer;
use proxlead::config::Config;
use proxlead::exp::{Experiment, ALGORITHM_NAMES};
use proxlead::graph::{Graph, MixingOp, MixingRule};
use proxlead::linalg::Mat;
use proxlead::oracle::OracleKind;
use proxlead::problem::data::{blobs, BlobSpec};
use proxlead::problem::{LogReg, Problem};
use proxlead::prox::L1;

const PROBLEMS: &[&str] = &["logreg", "least-squares", "lasso"];

fn tiny(problem: &str, algorithm: &str) -> Config {
    Config::parse(&format!(
        "problem = {problem}\nalgorithm = {algorithm}\nnodes = 4\nsamples_per_node = 24\n\
         dim = 6\nclasses = 3\nbatches = 4\nlambda1 = 0.005\nlambda2 = 0.1\n\
         separation = 1.0\nbits = 2\n"
    ))
    .expect("tiny config")
}

/// Every algorithm × every problem: constructs and stays finite for 50
/// rounds. This is the "compression is almost free across scenarios" grid
/// the paper's claim needs to be cheap to extend.
#[test]
fn algorithm_problem_matrix_runs_finite() {
    for problem in PROBLEMS {
        for algorithm in ALGORITHM_NAMES {
            let mut cfg = tiny(problem, algorithm);
            if *algorithm == "choco" {
                cfg.gamma = 0.2; // gossip stepsize convention
            }
            let exp = Experiment::from_config(&cfg)
                .unwrap_or_else(|e| panic!("{problem} × {algorithm}: {e}"));
            let mut alg = exp.algorithm_with_seed(3);
            for round in 0..50 {
                alg.step(exp.problem.as_ref());
                assert!(
                    alg.x().is_finite(),
                    "{problem} × {algorithm}: non-finite at round {round}"
                );
            }
            assert!(alg.bits() > 0 || alg.grad_evals() > 0, "{problem} × {algorithm} idle");
        }
    }
}

/// The pre-refactor construction path: BlobSpec → LogReg, Graph::ring,
/// positional `ProxLead::new` — exactly what `sparse_dense_equiv` pinned
/// before the Experiment API existed.
fn legacy_ring32() -> (LogReg, MixingOp) {
    let spec = BlobSpec {
        nodes: 32,
        samples_per_node: 12,
        dim: 6,
        classes: 3,
        separation: 1.0,
        seed: 41,
        ..Default::default()
    };
    let p = LogReg::new(blobs(&spec), 3, 0.1, 4);
    let g = Graph::ring(32);
    let w = MixingOp::build(&g, MixingRule::UniformMaxDegree);
    (p, w)
}

/// The pin: Experiment-built Prox-LEAD ≡ legacy constructor-built
/// Prox-LEAD, bit for bit, 200 rounds on ring-32 with 2-bit quantization.
#[test]
fn experiment_reproduces_prerefactor_iterates_bit_for_bit() {
    // legacy side
    let (p, w) = legacy_ring32();
    let x0 = Mat::zeros(32, p.dim());
    let mut legacy = ProxLead::new(
        &p,
        &w,
        &x0,
        Hyper::paper_default(0.5 / p.smoothness()),
        OracleKind::Full,
        Box::new(InfNormQuantizer::new(2, 256)),
        Box::new(L1::new(5e-3)),
        7,
    );

    // Experiment side: the same fixture spelled as a config
    let cfg = Config::parse(
        "nodes = 32\nsamples_per_node = 12\ndim = 6\nclasses = 3\nbatches = 4\n\
         separation = 1.0\nseed = 41\nlambda1 = 0.005\nlambda2 = 0.1\nbits = 2\n",
    )
    .unwrap();
    let exp = Experiment::from_config(&cfg).unwrap();
    let mut modern = exp.algorithm_with_seed(7);

    for round in 0..200 {
        let sl = legacy.step(&p);
        let sm = modern.step(exp.problem.as_ref());
        assert_eq!(sl.bits, sm.bits, "round {round}: wire bits diverged");
        for (i, (a, b)) in legacy.x().data.iter().zip(&modern.x().data).enumerate() {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "round {round}, entry {i}: {a:?} (legacy) vs {b:?} (experiment)"
            );
        }
    }
    assert_eq!(legacy.bits(), modern.bits());
    assert_eq!(legacy.grad_evals(), modern.grad_evals());
    assert!(legacy.x().norm_sq() > 0.0, "fixture must make progress");
}

/// `problem = least-squares` as a sweep cell runs end to end and produces
/// a finite, shrinking trace (the acceptance scenario for the new axis).
#[test]
fn least_squares_sweep_cell_end_to_end() {
    use proxlead::sweep::{run_sweep, SweepSpec};
    let base = Config::parse(
        "nodes = 4\nsamples_per_node = 24\ndim = 8\nbatches = 4\nlambda1 = 0.005\n\
         lambda2 = 0.1\nrounds = 400\nrecord_every = 100\n",
    )
    .unwrap();
    let spec = SweepSpec::new(base)
        .variant(&[("problem", "least-squares"), ("algorithm", "prox-lead"), ("bits", "2")])
        .variant(&[("problem", "lasso"), ("algorithm", "prox-lead"), ("bits", "2")])
        .threads(2);
    let res = run_sweep(&spec, |_| {}).unwrap();
    assert_eq!(res.cells.len(), 2);
    for cell in &res.cells {
        let first = cell.result.history.first().unwrap().suboptimality;
        let last = cell.final_subopt();
        assert!(last.is_finite());
        assert!(last < first, "quadratic cell should descend: {first} → {last}");
        assert_eq!(cell.result.final_x.cols, 8, "regression p = dim");
    }
}

/// The sim and the coordinator share one frame format, whose `from` field
/// is a u16: a config asking either backend for more nodes than that must
/// be rejected with a typed error up front — not silently truncate sender
/// ids in `WireFault` reports. Validation stays cheap (no data is
/// generated), so the rejection costs nothing.
#[test]
fn sim_backend_rejects_more_nodes_than_u16_ids() {
    for backend in ["sim", "coordinator"] {
        let mut cfg = tiny("logreg", "prox-lead");
        cfg.backend = backend.into();
        cfg.nodes = 70_000;
        let err = proxlead::exp::validate_config(&cfg)
            .expect_err(&format!("70k-node {backend} must be rejected"));
        let msg = err.to_string();
        assert!(msg.contains(backend), "error must name the backend: {msg}");
        assert!(msg.contains("65535"), "error must name the limit: {msg}");
        assert!(msg.contains("u16"), "error must explain the wire-format cause: {msg}");
        assert!(msg.contains("70000"), "error must echo the offending value: {msg}");
        // the boundary itself is representable and passes the same validation
        cfg.nodes = 65_535;
        proxlead::exp::validate_config(&cfg)
            .unwrap_or_else(|e| panic!("65535 nodes is exactly representable ({backend}): {e}"));
    }
}

/// A socket transport only makes sense under the coordinator backend, and
/// needs an address to bind; both mistakes must be caught by the same
/// cheap validation pass the sweep runtime uses.
#[test]
fn socket_transport_config_is_validated_up_front() {
    let mut cfg = tiny("logreg", "prox-lead");
    cfg.backend = "coordinator".into();
    cfg.transport = "tcp".into();
    let err = proxlead::exp::validate_config(&cfg).expect_err("tcp without bind must be rejected");
    assert!(err.to_string().contains("bind"), "error must name the missing key: {err}");
    cfg.bind = "127.0.0.1:7070".into();
    proxlead::exp::validate_config(&cfg).expect("tcp + bind under coordinator is valid");
    cfg.backend = "sim".into();
    let err = proxlead::exp::validate_config(&cfg).expect_err("tcp under sim must be rejected");
    assert!(err.to_string().contains("coordinator"), "error must name the required backend: {err}");
    cfg.backend = "coordinator".into();
    cfg.transport = "carrier-pigeon".into();
    let err = proxlead::exp::validate_config(&cfg).expect_err("unknown transport must be rejected");
    assert!(err.to_string().contains("carrier-pigeon"), "error must echo the value: {err}");
}

/// Builder overrides flow into the constructed algorithm (name/oracle) and
/// the experiment's auto-η matches the problem the registry built.
#[test]
fn builder_overrides_and_auto_eta() {
    let exp = Experiment::from_config(&tiny("least-squares", "prox-lead")).unwrap();
    assert!((exp.hyper.eta - 0.5 / exp.problem.smoothness()).abs() < 1e-15);
    let alg = ProxLead::builder(&exp).oracle(OracleKind::Saga).tag("2bit").build();
    assert_eq!(alg.name(), "Prox-LEAD (2bit, saga) 2bit");
    let lead = ProxLead::builder(&exp).prox(Box::new(proxlead::prox::Zero)).build();
    assert!(lead.name().starts_with("LEAD"));
}
