//! Cross-module integration tests of the paper's structural invariants —
//! properties that hold along the whole trajectory, not just at the fixed
//! point. Every algorithm is constructed through the Experiment API.

use proxlead::algorithm::{solve_reference, suboptimality, Algorithm, ProxLead};
use proxlead::config::Config;
use proxlead::exp::Experiment;
use proxlead::oracle::OracleKind;
use proxlead::prox::{GroupLasso, Prox};

/// The historical ring-logreg fixture (24 samples/node, d = 5, C = 3,
/// λ₂ = 0.1) as a resolved Experiment: auto-η = 1/(2L), uniform ring
/// mixing, 2-bit ∞-norm compressor, ℓ1(5e-3) prox.
fn fixture(nodes: usize, seed: u64) -> Experiment {
    let cfg = Config::parse(&format!(
        "nodes = {nodes}\nsamples_per_node = 24\ndim = 5\nclasses = 3\nbatches = 4\n\
         separation = 1.0\nseed = {seed}\nlambda1 = 0.005\nlambda2 = 0.1\nbits = 2\n"
    ))
    .expect("fixture config");
    Experiment::from_config(&cfg).expect("fixture experiment")
}

/// The dual variable lives in range(I − W): its column sums are zero for
/// the whole trajectory (the paper's D* = (I − 11ᵀ/n)∇F(X*) needs this).
#[test]
fn dual_variable_column_sums_stay_zero() {
    let exp = fixture(5, 3);
    let p = exp.problem.as_ref();
    let mut alg = ProxLead::builder(&exp).oracle(OracleKind::Sgd).seed(9).build();
    for k in 0..300 {
        alg.step(p);
        if k % 50 == 0 {
            let d = alg.d();
            for j in 0..d.cols {
                let col_sum: f64 = (0..d.rows).map(|i| d[(i, j)]).sum();
                let scale = d.norm().max(1.0);
                assert!(
                    col_sum.abs() < 1e-9 * scale,
                    "round {k}: 1ᵀD ≠ 0 at col {j}: {col_sum}"
                );
            }
        }
    }
}

/// §5 robustness claim: α = 0.5, γ = 1 "for all experiments" — the method
/// converges across a wide grid of (α, γ) without retuning.
#[test]
fn robust_to_alpha_gamma_grid() {
    let exp = fixture(4, 7);
    let p = exp.problem.as_ref();
    let x_star = solve_reference(p, 5e-3, 40_000, 1e-13);
    for alpha in [0.1, 0.3, 0.5, 0.7] {
        for gamma in [0.25, 0.5, 1.0] {
            let mut alg = ProxLead::builder(&exp).alpha(alpha).gamma(gamma).seed(13).build();
            for _ in 0..5000 {
                alg.step(p);
            }
            let s = suboptimality(alg.x(), &x_star);
            assert!(s < 1e-9, "diverged/stalled at α={alpha}, γ={gamma}: {s}");
        }
    }
}

/// Convergence is topology-independent in the limit (only the rate moves
/// with κ_g): same fixed point on ring/star/complete/chain/ER.
#[test]
fn same_fixed_point_across_topologies() {
    let base = fixture(6, 11);
    let x_star = solve_reference(base.problem.as_ref(), 5e-3, 40_000, 1e-13);
    for topo in ["ring", "chain", "star", "complete", "er"] {
        let mut cfg = base.config.clone();
        cfg.set("topology", topo).unwrap();
        cfg.set("mixing", "mh").unwrap();
        let exp = Experiment::from_config(&cfg).unwrap();
        assert!(exp.mixing.gap_estimate().kappa_g().is_finite());
        let p = exp.problem.as_ref();
        let mut alg = ProxLead::builder(&exp).seed(3).build();
        for _ in 0..8000 {
            alg.step(p);
        }
        let s = suboptimality(alg.x(), &x_star);
        assert!(s < 1e-10, "{topo}: suboptimality {s}");
    }
}

/// Heterogeneity ablation: Prox-LEAD needs NO bounded-heterogeneity
/// assumption — label-sorted (extreme) and shuffled (iid) partitions both
/// converge to their references at comparable rates.
#[test]
fn heterogeneity_does_not_break_convergence() {
    for shuffled in [false, true] {
        let cfg = Config::parse(&format!(
            "nodes = 4\nsamples_per_node = 24\ndim = 5\nclasses = 3\nbatches = 4\n\
             separation = 1.0\nseed = 21\nlambda1 = 0\nlambda2 = 0.1\nbits = 2\n\
             shuffled = {shuffled}\n"
        ))
        .unwrap();
        let exp = Experiment::from_config(&cfg).unwrap();
        let p = exp.problem.as_ref();
        let x_star = solve_reference(p, 0.0, 40_000, 1e-13);
        // λ1 = 0 ⇒ the experiment's default prox is already r ≡ 0
        assert!(exp.prox().is_zero());
        let mut alg = ProxLead::builder(&exp).seed(3).build();
        for _ in 0..4000 {
            alg.step(p);
        }
        let s = suboptimality(alg.x(), &x_star);
        assert!(s < 1e-12, "shuffled = {shuffled}: {s}");
    }
}

/// The shared-r requirement supports any proximable r: group lasso drives
/// whole feature groups to zero and still converges to the FISTA reference.
#[test]
fn group_lasso_composite_converges() {
    let exp = fixture(4, 17);
    let p = exp.problem.as_ref();
    let r = GroupLasso::new(0.02, 3);
    let x_star = proxlead::algorithm::reference::solve_reference_prox(p, &r, 60_000, 1e-12);
    let mut alg =
        ProxLead::builder(&exp).prox(Box::new(GroupLasso::new(0.02, 3))).seed(3).build();
    for _ in 0..6000 {
        alg.step(p);
    }
    let s = suboptimality(alg.x(), &x_star);
    assert!(s < 1e-10, "group-lasso suboptimality {s}");
    // group structure: zeroed coordinates come in aligned triples
    let xbar = alg.x().row_mean();
    for chunk in xbar.chunks(3) {
        let zeros = chunk.iter().filter(|v| v.abs() < 1e-9).count();
        assert!(zeros == 0 || zeros == chunk.len(), "partial group zeroing: {chunk:?}");
    }
    let _ = r.eval(&xbar);
}

/// Consensus error must go to zero even though individual iterates start
/// identical and data is heterogeneous (the I−W constraint is active).
#[test]
fn consensus_error_vanishes() {
    let exp = fixture(4, 23);
    let p = exp.problem.as_ref();
    let mut alg = ProxLead::builder(&exp).seed(3).build();
    let mut early = 0.0;
    for k in 0..4000 {
        alg.step(p);
        if k == 100 {
            early = alg.x().consensus_error();
        }
    }
    let late = alg.x().consensus_error();
    assert!(early > 0.0, "heterogeneous gradients must create disagreement");
    assert!(late < early * 1e-6, "consensus error should vanish: {late} vs {early}");
}
