//! Cross-module integration tests of the paper's structural invariants —
//! properties that hold along the whole trajectory, not just at the fixed
//! point.

use proxlead::algorithm::{solve_reference, suboptimality, Algorithm, Hyper, ProxLead};
use proxlead::compress::InfNormQuantizer;
use proxlead::graph::{Graph, MixingOp, MixingRule, Topology};
use proxlead::linalg::Mat;
use proxlead::oracle::OracleKind;
use proxlead::problem::data::{blobs, BlobSpec, Partition};
use proxlead::problem::{LogReg, Problem};
use proxlead::prox::{GroupLasso, Prox, Zero, L1};
use proxlead::util::rng::Rng;

fn fixture(nodes: usize, seed: u64) -> (LogReg, MixingOp) {
    let spec = BlobSpec {
        nodes,
        samples_per_node: 24,
        dim: 5,
        classes: 3,
        separation: 1.0,
        seed,
        ..Default::default()
    };
    let p = LogReg::new(blobs(&spec), 3, 0.1, 4);
    let g = Graph::ring(nodes);
    let w = MixingOp::build(&g, MixingRule::UniformMaxDegree);
    (p, w)
}

/// The dual variable lives in range(I − W): its column sums are zero for
/// the whole trajectory (the paper's D* = (I − 11ᵀ/n)∇F(X*) needs this).
#[test]
fn dual_variable_column_sums_stay_zero() {
    let (p, w) = fixture(5, 3);
    let x0 = Mat::zeros(5, p.dim());
    let mut alg = ProxLead::new(
        &p,
        &w,
        &x0,
        Hyper::paper_default(0.5 / p.smoothness()),
        OracleKind::Sgd,
        Box::new(InfNormQuantizer::new(2, 256)),
        Box::new(L1::new(5e-3)),
        9,
    );
    for k in 0..300 {
        alg.step(&p);
        if k % 50 == 0 {
            let d = alg.d();
            for j in 0..d.cols {
                let col_sum: f64 = (0..d.rows).map(|i| d[(i, j)]).sum();
                let scale = d.norm().max(1.0);
                assert!(
                    col_sum.abs() < 1e-9 * scale,
                    "round {k}: 1ᵀD ≠ 0 at col {j}: {col_sum}"
                );
            }
        }
    }
}

/// §5 robustness claim: α = 0.5, γ = 1 "for all experiments" — the method
/// converges across a wide grid of (α, γ) without retuning.
#[test]
fn robust_to_alpha_gamma_grid() {
    let (p, w) = fixture(4, 7);
    let x_star = solve_reference(&p, 5e-3, 40_000, 1e-13);
    let x0 = Mat::zeros(4, p.dim());
    let eta = 0.5 / p.smoothness();
    for alpha in [0.1, 0.3, 0.5, 0.7] {
        for gamma in [0.25, 0.5, 1.0] {
            let mut alg = ProxLead::new(
                &p,
                &w,
                &x0,
                Hyper { eta, alpha, gamma },
                OracleKind::Full,
                Box::new(InfNormQuantizer::new(2, 256)),
                Box::new(L1::new(5e-3)),
                13,
            );
            for _ in 0..5000 {
                alg.step(&p);
            }
            let s = suboptimality(alg.x(), &x_star);
            assert!(s < 1e-9, "diverged/stalled at α={alpha}, γ={gamma}: {s}");
        }
    }
}

/// Convergence is topology-independent in the limit (only the rate moves
/// with κ_g): same fixed point on ring/star/complete/chain/ER.
#[test]
fn same_fixed_point_across_topologies() {
    let (p, _) = fixture(6, 11);
    let x_star = solve_reference(&p, 5e-3, 40_000, 1e-13);
    let x0 = Mat::zeros(6, p.dim());
    for topo in
        [Topology::Ring, Topology::Chain, Topology::Star, Topology::Complete, Topology::ErdosRenyi]
    {
        let g = Graph::build(topo, 6, &mut Rng::new(5));
        let w = MixingOp::build(&g, MixingRule::Metropolis);
        assert!(w.gap_estimate().kappa_g().is_finite());
        let mut alg = ProxLead::new(
            &p,
            &w,
            &x0,
            Hyper::paper_default(0.5 / p.smoothness()),
            OracleKind::Full,
            Box::new(InfNormQuantizer::new(2, 256)),
            Box::new(L1::new(5e-3)),
            3,
        );
        for _ in 0..8000 {
            alg.step(&p);
        }
        let s = suboptimality(alg.x(), &x_star);
        assert!(s < 1e-10, "{topo:?}: suboptimality {s}");
    }
}

/// Heterogeneity ablation: Prox-LEAD needs NO bounded-heterogeneity
/// assumption — label-sorted (extreme) and shuffled (iid) partitions both
/// converge to their references at comparable rates.
#[test]
fn heterogeneity_does_not_break_convergence() {
    for partition in [Partition::LabelSorted, Partition::Shuffled] {
        let spec = BlobSpec {
            nodes: 4,
            samples_per_node: 24,
            dim: 5,
            classes: 3,
            separation: 1.0,
            partition,
            seed: 21,
            ..Default::default()
        };
        let p = LogReg::new(blobs(&spec), 3, 0.1, 4);
        let g = Graph::ring(4);
        let w = MixingOp::build(&g, MixingRule::UniformMaxDegree);
        let x_star = solve_reference(&p, 0.0, 40_000, 1e-13);
        let x0 = Mat::zeros(4, p.dim());
        let mut alg = ProxLead::new(
            &p,
            &w,
            &x0,
            Hyper::paper_default(0.5 / p.smoothness()),
            OracleKind::Full,
            Box::new(InfNormQuantizer::new(2, 256)),
            Box::new(Zero),
            3,
        );
        for _ in 0..4000 {
            alg.step(&p);
        }
        let s = suboptimality(alg.x(), &x_star);
        assert!(s < 1e-12, "{partition:?}: {s}");
    }
}

/// The shared-r requirement supports any proximable r: group lasso drives
/// whole feature groups to zero and still converges to the FISTA reference.
#[test]
fn group_lasso_composite_converges() {
    let (p, w) = fixture(4, 17);
    let r = GroupLasso::new(0.02, 3);
    let x_star = proxlead::algorithm::reference::solve_reference_prox(&p, &r, 60_000, 1e-12);
    let x0 = Mat::zeros(4, p.dim());
    let mut alg = ProxLead::new(
        &p,
        &w,
        &x0,
        Hyper::paper_default(0.5 / p.smoothness()),
        OracleKind::Full,
        Box::new(InfNormQuantizer::new(2, 256)),
        Box::new(GroupLasso::new(0.02, 3)),
        3,
    );
    for _ in 0..6000 {
        alg.step(&p);
    }
    let s = suboptimality(alg.x(), &x_star);
    assert!(s < 1e-10, "group-lasso suboptimality {s}");
    // group structure: zeroed coordinates come in aligned triples
    let xbar = alg.x().row_mean();
    for chunk in xbar.chunks(3) {
        let zeros = chunk.iter().filter(|v| v.abs() < 1e-9).count();
        assert!(zeros == 0 || zeros == chunk.len(), "partial group zeroing: {chunk:?}");
    }
    let _ = r.eval(&xbar);
}

/// Consensus error must go to zero even though individual iterates start
/// identical and data is heterogeneous (the I−W constraint is active).
#[test]
fn consensus_error_vanishes() {
    let (p, w) = fixture(4, 23);
    let x0 = Mat::zeros(4, p.dim());
    let mut alg = ProxLead::new(
        &p,
        &w,
        &x0,
        Hyper::paper_default(0.5 / p.smoothness()),
        OracleKind::Full,
        Box::new(InfNormQuantizer::new(2, 256)),
        Box::new(L1::new(5e-3)),
        3,
    );
    let mut early = 0.0;
    for k in 0..4000 {
        alg.step(&p);
        if k == 100 {
            early = alg.x().consensus_error();
        }
    }
    let late = alg.x().consensus_error();
    assert!(early > 0.0, "heterogeneous gradients must create disagreement");
    assert!(late < early * 1e-6, "consensus error should vanish: {late} vs {early}");
}
