//! Transport acceptance (ISSUE-10): the socket transports are the same
//! coordinator, only the bytes travel farther.
//!
//! 1. **Parity** — a loopback Tcp and a loopback Unix run are
//!    bit-identical to the in-process transport: final iterate, every
//!    history row (cumulative wire bits and framed bytes included), and
//!    the stop reason. Checked for Prox-LEAD under `Dense64`, Prox-LEAD
//!    under 2-bit quantization, and DGD under `Dense64`.
//! 2. **Fault** — a node process that dies mid-run (handshake, then
//!    silence) must surface as a typed
//!    `WireError::Transport(TransportError::Eof)` stop attributed to the
//!    dead node, within a bounded wall-clock budget — never a hang.
//! 3. The handshake fingerprint tracks config semantics, not output
//!    paths, so leader and workers agree on "same experiment".

use std::sync::mpsc;
use std::thread;
use std::time::Duration;

use proxlead::config::Config;
use proxlead::coordinator::WireError;
use proxlead::exp::Experiment;
use proxlead::runner::{RunResult, StopReason};
use proxlead::transport::{dial, DialAddr, Hello, Transport, TransportError};

fn ring_exp(algorithm: &str, bits: u32, rounds: usize) -> Experiment {
    let cfg = Config::parse(&format!(
        "algorithm = {algorithm}\ntopology = ring\nnodes = 4\nsamples_per_node = 6\n\
         dim = 3\nclasses = 2\nbatches = 2\nseed = 13\nlambda1 = 0.005\nlambda2 = 0.1\n\
         bits = {bits}\nrounds = {rounds}\nrecord_every = 2\n"
    ))
    .expect("config parses");
    Experiment::from_config(&cfg).expect("experiment resolves")
}

/// Run `f` on a worker thread; fail the test if it has not finished
/// within `secs` (a hung teardown shows up as a timeout, not a CI hang).
fn with_watchdog<T: Send + 'static>(secs: u64, f: impl FnOnce() -> T + Send + 'static) -> T {
    let (tx, rx) = mpsc::channel();
    let h = thread::spawn(move || {
        let _ = tx.send(f());
    });
    match rx.recv_timeout(Duration::from_secs(secs)) {
        Ok(v) => {
            h.join().expect("watchdog worker panicked");
            v
        }
        Err(_) => panic!("run did not complete within {secs}s — transport liveness regression"),
    }
}

fn assert_bit_identical(base: &RunResult, got: &RunResult, label: &str) {
    assert_eq!(base.stopped_by, got.stopped_by, "{label}: stop reason diverged");
    assert_eq!(base.history.len(), got.history.len(), "{label}: history row count diverged");
    for (b, g) in base.history.iter().zip(&got.history) {
        let at = format!("{label}: round {}", b.round);
        assert_eq!(b.round, g.round, "{at}: row order diverged");
        assert_eq!(b.grad_evals, g.grad_evals, "{at}: grad evals diverged");
        assert_eq!(b.bits, g.bits, "{at}: cumulative wire bits diverged");
        assert_eq!(b.wire_bytes, g.wire_bytes, "{at}: cumulative framed bytes diverged");
        assert_eq!(
            b.suboptimality.to_bits(),
            g.suboptimality.to_bits(),
            "{at}: suboptimality diverged"
        );
        assert_eq!(b.consensus.to_bits(), g.consensus.to_bits(), "{at}: consensus diverged");
    }
    assert_eq!(
        (base.final_x.rows, base.final_x.cols),
        (got.final_x.rows, got.final_x.cols),
        "{label}: final iterate shape diverged"
    );
    for (i, (a, b)) in base.final_x.data.iter().zip(&got.final_x.data).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "{label}: final_x entry {i}: {a:?} vs {b:?}");
    }
}

/// One algorithm × codec cell: in-process baseline, then loopback Tcp and
/// loopback Unix, all three compared bit for bit.
fn parity_case(algorithm: &'static str, bits: u32) {
    let (base, tcp, unix) = with_watchdog(180, move || {
        let exp = ring_exp(algorithm, bits, 6);
        let spec = exp.run_spec();
        let base = exp.run_coordinator(&spec);
        let tcp = exp.run_coordinator_loopback(&spec, "tcp");
        let unix = exp.run_coordinator_loopback(&spec, "unix");
        (base, tcp, unix)
    });
    assert!(base.final_x.norm_sq() > 0.0, "{algorithm}/{bits}: fixture must make progress");
    assert_bit_identical(&base, &tcp, &format!("{algorithm}/{bits} tcp"));
    assert_bit_identical(&base, &unix, &format!("{algorithm}/{bits} unix"));
}

#[test]
fn prox_lead_dense64_is_bit_identical_across_transports() {
    parity_case("prox-lead", 64);
}

#[test]
fn prox_lead_quantized_is_bit_identical_across_transports() {
    parity_case("prox-lead", 2);
}

#[test]
fn dgd_dense64_is_bit_identical_across_transports() {
    parity_case("dgd", 64);
}

/// Handshake as the victim node, then die without sending a byte: the
/// leader's uplink must synthesize a `Transport(Eof)` fault for the dead
/// node, tear the survivors down through the ABORT protocol, and return
/// a typed stop — all inside the watchdog budget.
fn kill_case(kind: &'static str) {
    let exp = ring_exp("prox-lead", 64, 6);
    let victim: u16 = 2;
    let fp = exp.wire_fingerprint();
    let accept = Duration::from_secs(10);
    let (transport, addr) = match kind {
        "tcp" => {
            let l = std::net::TcpListener::bind("127.0.0.1:0").expect("bind kill-test tcp");
            let a = l.local_addr().expect("local addr").to_string();
            (Transport::tcp(l, fp, accept), DialAddr::Tcp(a))
        }
        "unix" => {
            let path =
                std::env::temp_dir().join(format!("proxlead-kill-{}.sock", std::process::id()));
            let _ = std::fs::remove_file(&path);
            let l = std::os::unix::net::UnixListener::bind(&path).expect("bind kill-test unix");
            (Transport::unix(l, fp, accept), DialAddr::Unix(path))
        }
        t => panic!("kill-test transport must be tcp or unix (got {t})"),
    };
    let sock_path = match &addr {
        DialAddr::Unix(p) => Some(p.clone()),
        DialAddr::Tcp(_) => None,
    };

    let res = with_watchdog(60, move || {
        let spec = exp.run_spec();
        let hello = Hello {
            fingerprint: fp,
            n: 4,
            dim: exp.problem.dim() as u32,
            rounds: spec.stop.max_rounds as u32,
            record_every: spec.record_every as u32,
            gated: spec.stop.leader_gated(),
        };
        thread::scope(|scope| {
            for i in 0..4usize {
                if i == victim as usize {
                    continue;
                }
                let addr = addr.clone();
                let (exp, spec) = (&exp, &spec);
                scope.spawn(move || {
                    // survivors run the real worker; they end via the
                    // leader's ABORT wave, which is not a worker error
                    let _ = exp.run_node_worker_at(spec, i, &addr);
                });
            }
            let addr = addr.clone();
            scope.spawn(move || {
                // the saboteur: a completed handshake, then sudden death
                let link = dial(&addr, victim, &hello, Duration::from_secs(10))
                    .expect("saboteur handshake must succeed");
                drop(link);
            });
            exp.run_coordinator_transport(&spec, &mut [], transport)
        })
    });
    if let Some(p) = sock_path {
        let _ = std::fs::remove_file(p);
    }

    match res.stopped_by {
        StopReason::WireFault(f) => {
            assert_eq!(f.node, victim, "{kind}: fault must name the dead node");
            assert_eq!(f.round, 0, "{kind}: the victim never spoke — fault is at round 0");
            assert!(
                matches!(f.error, WireError::Transport(TransportError::Eof)),
                "{kind}: expected Transport(Eof), got {:?}",
                f.error
            );
        }
        other => panic!("{kind}: expected a wire-fault stop, got {other:?}"),
    }
    assert_eq!(res.history.len(), 1, "{kind}: no round completes; round 0 is synthesized");
}

#[test]
fn killed_node_yields_typed_stop_on_tcp() {
    kill_case("tcp");
}

#[test]
fn killed_node_yields_typed_stop_on_unix() {
    kill_case("unix");
}

/// Leader and workers must agree on the handshake fingerprint exactly
/// when their configs describe the same experiment: where the JSON lands
/// is not part of "same experiment", but any semantic key is.
#[test]
fn wire_fingerprint_tracks_semantics_not_output_paths() {
    let exp = ring_exp("prox-lead", 64, 6);
    let mut same_run = exp.config.clone();
    same_run.out = "elsewhere.json".into();
    let same = Experiment::from_config(&same_run).expect("config resolves");
    assert_eq!(exp.wire_fingerprint(), same.wire_fingerprint(), "out path must not matter");

    let mut other_run = exp.config.clone();
    other_run.lambda1 = 0.1;
    let other = Experiment::from_config(&other_run).expect("config resolves");
    assert_ne!(exp.wire_fingerprint(), other.wire_fingerprint(), "semantics must matter");
}
