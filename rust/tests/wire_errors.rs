//! The corrupt-frame matrix: every malformed or protocol-violating input
//! must surface as a typed `WireError` — never a panic, never a hang.
//!
//! Two layers:
//! - frame/codec level: deterministic corruptions of real encoded frames,
//!   checked against `FrameRef::parse` + `WireCodec::decode_into` across
//!   all three codecs;
//! - end to end: `CoordConfig::tamper` corrupts one prescribed broadcast
//!   inside a live coordinator run; the run must return normally with
//!   `StopReason::WireFault` carrying the expected error kind (gated and
//!   ungated), with every node thread joined — the teardown protocol's
//!   no-deadlock guarantee.

#![allow(deprecated)] // run_prox_lead is the stable hand-wired entry point

use proxlead::config::Config;
use proxlead::coordinator::{
    self, CoordConfig, FrameRef, FrameTamper, NodeHyper, TamperKind, WireCodec, WireError,
};
use proxlead::exp::Experiment;
use proxlead::runner::{RunSpec, StopReason};
use proxlead::util::rng::Rng;
use std::mem::discriminant;
use std::sync::Arc;

/// A valid one-frame buffer for `codec` carrying an n-entry payload.
fn good_frame(codec: &WireCodec, n: usize, round: u32, from: u16) -> Vec<u8> {
    let mut rng = Rng::new(11);
    let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let (payload, _, _) = codec.encode(&x, &mut Rng::new(5));
    coordinator::Frame { round, from, payload }.to_bytes(codec)
}

/// Parse + decode the way the node receive path does, collapsed to the
/// first error.
fn receive(codec: &WireCodec, buf: &[u8], n: usize) -> Result<(), WireError> {
    let f = FrameRef::parse(buf)?;
    if f.tag != codec.tag() {
        return Err(if WireCodec::known_tag(f.tag) {
            WireError::TagMismatch { expected: codec.tag(), got: f.tag }
        } else {
            WireError::UnknownTag { tag: f.tag }
        });
    }
    let mut out = vec![0.0; n];
    codec.decode_into(f.payload, &mut out)
}

#[test]
fn corrupt_frames_return_typed_errors_across_all_codecs() {
    let n = 70; // spans a non-integral number of quant bytes
    for codec in [WireCodec::Dense64, WireCodec::Dense32, WireCodec::Quant(2, 64)] {
        let bytes = good_frame(&codec, n, 3, 1);
        assert_eq!(receive(&codec, &bytes, n), Ok(()), "{codec:?}: baseline frame must pass");

        // truncated header: fewer bytes than the fixed header
        assert_eq!(
            receive(&codec, &bytes[..6], n),
            Err(WireError::TruncatedHeader { len: 6 }),
            "{codec:?}"
        );

        // short payload: header promises more than was received
        let short = &bytes[..bytes.len() - 1];
        assert_eq!(
            receive(&codec, short, n),
            Err(WireError::TruncatedPayload { need: bytes.len(), got: bytes.len() - 1 }),
            "{codec:?}"
        );

        // overlong payload with a re-patched length: parses, codec rejects
        let mut long = bytes.clone();
        long.extend_from_slice(&[0u8; 8]);
        let plen = (long.len() - coordinator::Frame::HEADER_LEN) as u32;
        long[7..11].copy_from_slice(&plen.to_le_bytes());
        let e = receive(&codec, &long, n).unwrap_err();
        match codec {
            WireCodec::Quant(..) => assert!(
                matches!(e, WireError::TrailingBytes { .. }),
                "{codec:?}: spare whole bytes after the final block, got {e:?}"
            ),
            _ => assert!(
                matches!(e, WireError::PayloadSize { .. }),
                "{codec:?}: dense length check, got {e:?}"
            ),
        }

        // trailing garbage beyond the framed length
        let mut garbage = bytes.clone();
        garbage.extend_from_slice(&[0xDE, 0xAD]);
        assert!(
            matches!(receive(&codec, &garbage, n), Err(WireError::TrailingBytes { .. })),
            "{codec:?}"
        );

        // a tag no codec owns
        let mut unknown = bytes.clone();
        unknown[0] = 0x7E;
        assert_eq!(
            receive(&codec, &unknown, n),
            Err(WireError::UnknownTag { tag: 0x7E }),
            "{codec:?}"
        );

        // a valid tag that is not this run's codec
        let mut wrong = bytes.clone();
        wrong[0] = if wrong[0] == 0 { 1 } else { 0 };
        assert!(
            matches!(receive(&codec, &wrong, n), Err(WireError::TagMismatch { .. })),
            "{codec:?}"
        );

        // empty and pure-garbage buffers
        assert_eq!(receive(&codec, &[], n), Err(WireError::TruncatedHeader { len: 0 }));
        let mut junk_rng = Rng::new(9);
        let junk: Vec<u8> = (0..8).flat_map(|_| junk_rng.next_u64().to_le_bytes()).collect();
        let mut junk = junk;
        junk[0] = codec.tag(); // force the tag so the codec layer is reached
        let r = receive(&codec, &junk, n);
        assert!(r.is_err(), "{codec:?}: 64 random bytes cannot be a valid {n}-entry frame");
    }
}

#[test]
fn corrupt_quant_block_norm_is_rejected() {
    let codec = WireCodec::Quant(4, 64);
    let n = 128;
    let mut bytes = good_frame(&codec, n, 0, 2);
    // first 4 payload bytes are block 0's f32 norm, MSB-first
    let h = coordinator::Frame::HEADER_LEN;
    bytes[h..h + 4].copy_from_slice(&f32::NAN.to_bits().to_be_bytes());
    assert_eq!(receive(&codec, &bytes, n), Err(WireError::BadBlockNorm { block: 0 }));
    bytes[h..h + 4].copy_from_slice(&(-2.5f32).to_bits().to_be_bytes());
    assert_eq!(receive(&codec, &bytes, n), Err(WireError::BadBlockNorm { block: 0 }));
}

fn fixture() -> Experiment {
    let cfg = Config::parse(
        "nodes = 4\nsamples_per_node = 24\ndim = 5\nclasses = 3\nbatches = 4\n\
         lambda2 = 0.1\nseparation = 1.0\nbits = 2\n",
    )
    .expect("config");
    Experiment::from_config(&cfg).expect("experiment")
}

/// Run a short tampered coordinator round-trip and return the fault the
/// run reported.
fn tampered_run(
    exp: &Experiment,
    codec: WireCodec,
    tamper: FrameTamper,
    spec: &RunSpec,
) -> coordinator::WireFault {
    let x_star = vec![0.0; exp.problem.dim()];
    let wire = CoordConfig::new(codec).seed(7).tamper(tamper);
    let res = coordinator::run_prox_lead(
        Arc::clone(&exp.problem),
        &exp.mixing,
        &exp.x0,
        Arc::new(proxlead::prox::Zero),
        &NodeHyper::new(0.05),
        &wire,
        spec,
        &x_star,
    );
    assert!(!res.history.is_empty(), "faulted runs still carry their pre-fault history");
    assert!(res.final_x.rows == exp.x0.rows, "final iterate shape survives the fault");
    match res.stopped_by {
        StopReason::WireFault(f) => f,
        other => panic!("expected StopReason::WireFault, got {other:?}"),
    }
}

#[test]
fn tampered_broadcasts_stop_the_run_with_the_expected_fault() {
    let exp = fixture();
    let round = 3usize;
    // (tamper, an example of the expected error kind). The fault's round
    // is the *detecting* node's: decode-level errors fire exactly at the
    // tampered round, parse/tag-level ones may be caught one round early
    // (the receiver still gathering round r−1 parses every arrival).
    let cases: [(TamperKind, WireError, bool); 7] = [
        (TamperKind::TruncateHeader, WireError::TruncatedHeader { len: 0 }, false),
        (TamperKind::ShortPayload, WireError::TruncatedPayload { need: 0, got: 0 }, false),
        (TamperKind::OverlongPayload, WireError::TrailingBytes { expected: 0, got: 0 }, true),
        (TamperKind::TrailingGarbage, WireError::TrailingBytes { expected: 0, got: 0 }, false),
        (TamperKind::UnknownTag, WireError::UnknownTag { tag: 0 }, false),
        (TamperKind::WrongCodecTag, WireError::TagMismatch { expected: 0, got: 0 }, false),
        (TamperKind::BadQuantNorm, WireError::BadBlockNorm { block: 0 }, true),
    ];
    for (kind, expect, round_exact) in cases {
        let fault = tampered_run(
            &exp,
            WireCodec::Quant(2, 256),
            FrameTamper { node: 2, round, kind },
            &RunSpec::fixed(8).every(2),
        );
        assert_eq!(
            discriminant(&fault.error),
            discriminant(&expect),
            "{kind:?}: got {:?}",
            fault.error
        );
        if round_exact {
            assert_eq!(fault.round as usize, round, "{kind:?}: decode-level detection round");
        } else {
            assert!(
                (fault.round as usize) == round || (fault.round as usize) + 1 == round,
                "{kind:?}: detected at {}, tampered at {round}",
                fault.round
            );
        }
        // the detector is a gossip neighbor of the tampering node, never
        // the tamperer itself
        assert_ne!(fault.node, 2, "{kind:?}: the sender cannot detect its own corruption");
    }
}

#[test]
fn dense_codec_faults_end_to_end_too() {
    let exp = fixture();
    let fault = tampered_run(
        &exp,
        WireCodec::Dense64,
        FrameTamper { node: 0, round: 2, kind: TamperKind::OverlongPayload },
        &RunSpec::fixed(6).every(3),
    );
    assert!(
        matches!(fault.error, WireError::PayloadSize { .. }),
        "dense length check end to end, got {:?}",
        fault.error
    );
}

#[test]
fn gated_runs_tear_down_without_deadlock_on_a_fault() {
    // a leader-gated run (bits budget ⇒ checkpoint blocking) with a fault
    // between checkpoints: the leader must release every blocked node and
    // the fault must win over the budget in the reported stop reason
    let exp = fixture();
    let fault = tampered_run(
        &exp,
        WireCodec::Quant(2, 256),
        FrameTamper { node: 1, round: 5, kind: TamperKind::BadQuantNorm },
        &RunSpec::fixed(40).every(2).bits_budget(u64::MAX / 2),
    );
    assert_eq!(discriminant(&fault.error), discriminant(&WireError::BadBlockNorm { block: 0 }));
    assert_eq!(fault.round, 5);
}

#[test]
fn fault_in_the_first_round_still_produces_a_round_zero_history() {
    let exp = fixture();
    let x_star = vec![0.0; exp.problem.dim()];
    let wire = CoordConfig::new(WireCodec::Quant(2, 256))
        .seed(7)
        .tamper(FrameTamper { node: 0, round: 0, kind: TamperKind::TruncateHeader });
    let res = coordinator::run_prox_lead(
        Arc::clone(&exp.problem),
        &exp.mixing,
        &exp.x0,
        Arc::new(proxlead::prox::Zero),
        &NodeHyper::new(0.05),
        &wire,
        &RunSpec::fixed(8).every(2),
        &x_star,
    );
    assert!(matches!(res.stopped_by, StopReason::WireFault(_)));
    let first = res.history.first().unwrap();
    assert_eq!(first.round, 0, "round-0 snapshot survives an immediate fault");
    assert!(first.suboptimality.is_finite());
    assert_eq!(res.stopped_by.name(), "wire-fault");
}
