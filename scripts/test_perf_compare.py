#!/usr/bin/env python3
"""Tests for scripts/perf_compare.py error handling — stdlib only.

The contract under test (ISSUE 8 satellite): a malformed or empty
``BENCH_*.json`` on either side of the perf gate must produce a one-line
``error:`` message and a nonzero exit, never a Python traceback; valid
inputs keep their bootstrap/compare semantics. Run directly (CI does, on
a runner with no Rust toolchain)::

    python3 scripts/test_perf_compare.py
"""

from __future__ import annotations

import json
import subprocess
import sys
import tempfile
import unittest
from pathlib import Path

SCRIPT = Path(__file__).resolve().parent / "perf_compare.py"


def report(p50: float = 100.0, name: str = "bench-a", smoke: bool = True) -> dict:
    return {
        "schema": "proxlead-perf-v1",
        "name": "t",
        "smoke": smoke,
        "sets": [{"title": "set", "results": [{"name": name, "p50_ns": p50}]}],
    }


class PerfCompareCli(unittest.TestCase):
    def setUp(self) -> None:
        self._tmp = tempfile.TemporaryDirectory()
        self.dir = Path(self._tmp.name)
        self.addCleanup(self._tmp.cleanup)

    def write(self, fname: str, content) -> Path:
        p = self.dir / fname
        if isinstance(content, (dict, list)):
            p.write_text(json.dumps(content))
        else:
            p.write_text(content)
        return p

    def run_compare(self, *argv: str) -> subprocess.CompletedProcess:
        return subprocess.run(
            [sys.executable, str(SCRIPT), *argv],
            capture_output=True, text=True, check=False,
        )

    def assert_one_line_error(self, proc: subprocess.CompletedProcess, *needles: str) -> None:
        self.assertNotEqual(proc.returncode, 0, proc.stdout)
        combined = proc.stdout + proc.stderr
        self.assertNotIn("Traceback", combined, f"traceback leaked:\n{combined}")
        error_lines = [l for l in proc.stderr.splitlines() if l.startswith("error:")]
        self.assertEqual(len(error_lines), 1, f"want exactly one error line:\n{combined}")
        for needle in needles:
            self.assertIn(needle, error_lines[0])

    # --- malformed / empty inputs -----------------------------------------

    def test_malformed_baseline_is_one_line_error(self):
        base = self.write("BENCH_x.json", "{not json at all")
        cur = self.write("cur.json", report())
        proc = self.run_compare("--baseline", str(base), "--current", str(cur))
        self.assert_one_line_error(proc, "not valid JSON", "BENCH_x.json")

    def test_empty_baseline_is_one_line_error(self):
        base = self.write("BENCH_x.json", "")
        cur = self.write("cur.json", report())
        proc = self.run_compare("--baseline", str(base), "--current", str(cur))
        self.assert_one_line_error(proc, "is empty", "bench_baseline.sh")

    def test_whitespace_only_counts_as_empty(self):
        base = self.write("BENCH_x.json", "  \n\t\n")
        cur = self.write("cur.json", report())
        proc = self.run_compare("--baseline", str(base), "--current", str(cur))
        self.assert_one_line_error(proc, "is empty")

    def test_wrong_schema_is_one_line_error(self):
        base = self.write("BENCH_x.json", {"schema": "something-else"})
        cur = self.write("cur.json", report())
        proc = self.run_compare("--baseline", str(base), "--current", str(cur))
        self.assert_one_line_error(proc, "schema")

    def test_row_less_report_is_one_line_error(self):
        base = self.write("BENCH_x.json",
                          {"schema": "proxlead-perf-v1", "smoke": True, "sets": []})
        cur = self.write("cur.json", report())
        proc = self.run_compare("--baseline", str(base), "--current", str(cur))
        self.assert_one_line_error(proc, "no benchmark rows")

    def test_non_object_json_is_one_line_error(self):
        base = self.write("BENCH_x.json", [1, 2, 3])
        cur = self.write("cur.json", report())
        proc = self.run_compare("--baseline", str(base), "--current", str(cur))
        self.assert_one_line_error(proc, "expected a BenchReport object")

    def test_malformed_current_is_one_line_error(self):
        base = self.write("BENCH_x.json", report())
        cur = self.write("cur.json", '{"schema": "proxlead-perf-v1", "sets": [')
        proc = self.run_compare("--baseline", str(base), "--current", str(cur))
        self.assert_one_line_error(proc, "not valid JSON", "cur.json")

    # --- healthy paths stay intact ----------------------------------------

    def test_missing_baseline_is_bootstrap_mode(self):
        cur = self.write("cur.json", report())
        proc = self.run_compare("--baseline", str(self.dir / "absent.json"),
                                "--current", str(cur))
        self.assertEqual(proc.returncode, 0, proc.stderr)
        self.assertIn("bootstrap", proc.stdout)

    def test_within_tolerance_passes(self):
        base = self.write("BENCH_x.json", report(p50=100.0))
        cur = self.write("cur.json", report(p50=110.0))
        proc = self.run_compare("--baseline", str(base), "--current", str(cur))
        self.assertEqual(proc.returncode, 0, proc.stdout + proc.stderr)
        self.assertIn("no regression", proc.stdout)

    def test_regression_beyond_tolerance_fails(self):
        base = self.write("BENCH_x.json", report(p50=100.0))
        cur = self.write("cur.json", report(p50=200.0))
        proc = self.run_compare("--baseline", str(base), "--current", str(cur))
        self.assertEqual(proc.returncode, 1, proc.stdout + proc.stderr)
        self.assertIn("FAIL", proc.stdout)

    # --- one-sided rows warn-and-skip (stale baselines never gate) ---------

    def test_stale_baseline_row_is_skipped_with_warning(self):
        # a bench retired from the harness leaves its row behind in the
        # committed baseline; the gate must warn and compare the rest
        base = self.write("BENCH_x.json", {
            "schema": "proxlead-perf-v1", "name": "t", "smoke": True,
            "sets": [{"title": "set", "results": [
                {"name": "bench-a", "p50_ns": 100.0},
                {"name": "retired-bench", "p50_ns": 50.0},
            ]}],
        })
        cur = self.write("cur.json", report(p50=100.0))
        proc = self.run_compare("--baseline", str(base), "--current", str(cur))
        self.assertEqual(proc.returncode, 0, proc.stdout + proc.stderr)
        self.assertIn("only in baseline", proc.stdout)
        self.assertIn("skipped 1 one-sided", proc.stdout)
        self.assertIn("no regression", proc.stdout)

    def test_new_current_row_is_skipped_until_baseline_lands(self):
        # the mirror image: a freshly added bench row (e.g. the loopback
        # transport row) must not fail before its baseline is committed
        base = self.write("BENCH_x.json", report(p50=100.0))
        cur = self.write("cur.json", {
            "schema": "proxlead-perf-v1", "name": "t", "smoke": True,
            "sets": [{"title": "set", "results": [
                {"name": "bench-a", "p50_ns": 100.0},
                {"name": "tcp-loopback", "p50_ns": 900.0},
            ]}],
        })
        proc = self.run_compare("--baseline", str(base), "--current", str(cur))
        self.assertEqual(proc.returncode, 0, proc.stdout + proc.stderr)
        self.assertIn("new row (no baseline yet)", proc.stdout)
        self.assertIn("no regression", proc.stdout)

    def test_fully_disjoint_rows_warn_instead_of_failing(self):
        base = self.write("BENCH_x.json", report(name="old-bench"))
        cur = self.write("cur.json", report(name="new-bench"))
        proc = self.run_compare("--baseline", str(base), "--current", str(cur))
        self.assertEqual(proc.returncode, 0, proc.stdout + proc.stderr)
        self.assertIn("share no benchmark rows", proc.stdout)

    # --- the --validate mode bench_baseline.sh relies on -------------------

    def test_validate_accepts_good_report(self):
        good = self.write("fresh.json", report())
        proc = self.run_compare("--validate", str(good))
        self.assertEqual(proc.returncode, 0, proc.stderr)
        self.assertIn("ok:", proc.stdout)

    def test_validate_rejects_empty_report(self):
        bad = self.write("fresh.json", "")
        proc = self.run_compare("--validate", str(bad))
        self.assert_one_line_error(proc, "is empty")

    def test_validate_rejects_missing_file(self):
        proc = self.run_compare("--validate", str(self.dir / "absent.json"))
        self.assert_one_line_error(proc, "not found")


if __name__ == "__main__":
    unittest.main(verbosity=2)
