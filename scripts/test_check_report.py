#!/usr/bin/env python3
"""Tests for scripts/check_report.py — stdlib only.

The contract under test (ISSUE 9 satellite): the ``proxlead-check-v1``
report written by ``cargo run --bin check -- --json`` round-trips through
the validator with the binary's own exit-code convention — 0 clean,
1 findings / coverage shortfall, 2 unreadable or schema-invalid input
(one ``error:`` line, never a traceback). Run directly (CI does, on a
runner with no Rust toolchain)::

    python3 scripts/test_check_report.py
"""

from __future__ import annotations

import json
import subprocess
import sys
import tempfile
import unittest
from pathlib import Path

SCRIPT = Path(__file__).resolve().parent / "check_report.py"


def scenario(name: str = "sim-ring-phases", ok: bool = True, distinct: int = 1200) -> dict:
    """One scenario entry shaped exactly like the Rust emitter's."""
    findings = [] if ok else [{"kind": "race", "detail": "sim.round: unordered store/load"}]
    return {
        "name": name,
        "pass": ok,
        "executions": 1400,
        "distinct_schedules": distinct,
        "dfs_executions": 300,
        "random_executions": 1100,
        "max_steps": 412,
        "schedule_invariant": True,
        "outcomes": ["max-rounds#00000000deadbeef"],
        "findings": findings,
    }


def report(scenarios: list | None = None) -> dict:
    scenarios = scenarios if scenarios is not None else [scenario()]
    return {
        "schema": "proxlead-check-v1",
        "pass": all(s["pass"] for s in scenarios),
        "scenarios": scenarios,
    }


class CheckReportCli(unittest.TestCase):
    def setUp(self) -> None:
        self._tmp = tempfile.TemporaryDirectory()
        self.dir = Path(self._tmp.name)
        self.addCleanup(self._tmp.cleanup)

    def write(self, content) -> Path:
        p = self.dir / "check_report.json"
        p.write_text(json.dumps(content) if isinstance(content, (dict, list)) else content)
        return p

    def run_validator(self, *argv: str) -> subprocess.CompletedProcess:
        return subprocess.run(
            [sys.executable, str(SCRIPT), *argv],
            capture_output=True, text=True, check=False,
        )

    def assert_schema_error(self, proc: subprocess.CompletedProcess, *needles: str) -> None:
        self.assertEqual(proc.returncode, 2, proc.stdout + proc.stderr)
        combined = proc.stdout + proc.stderr
        self.assertNotIn("Traceback", combined, f"traceback leaked:\n{combined}")
        error_lines = [l for l in proc.stderr.splitlines() if l.startswith("error:")]
        self.assertEqual(len(error_lines), 1, f"want exactly one error line:\n{combined}")
        for needle in needles:
            self.assertIn(needle, error_lines[0])

    # -- exit 0: clean round-trip -------------------------------------

    def test_passing_report_exits_zero(self) -> None:
        proc = self.run_validator(str(self.write(report())))
        self.assertEqual(proc.returncode, 0, proc.stdout + proc.stderr)
        self.assertIn("1 scenario(s) clean", proc.stdout)

    def test_min_distinct_floor_met_exits_zero(self) -> None:
        p = self.write(report([scenario(distinct=1000)]))
        proc = self.run_validator(str(p), "--min-distinct", "1000")
        self.assertEqual(proc.returncode, 0, proc.stdout + proc.stderr)

    # -- exit 1: valid report, failing content ------------------------

    def test_findings_exit_one_and_are_printed(self) -> None:
        p = self.write(report([scenario(), scenario(name="coord-fault-teardown", ok=False)]))
        proc = self.run_validator(str(p))
        self.assertEqual(proc.returncode, 1, proc.stdout + proc.stderr)
        self.assertIn("coord-fault-teardown: race:", proc.stdout)
        self.assertIn("1/2 scenario(s) failed", proc.stdout)

    def test_min_distinct_shortfall_exits_one(self) -> None:
        p = self.write(report([scenario(distinct=999)]))
        proc = self.run_validator(str(p), "--min-distinct", "1000")
        self.assertEqual(proc.returncode, 1, proc.stdout + proc.stderr)
        self.assertIn("below the --min-distinct 1000 floor", proc.stdout)

    # -- exit 2: unreadable or schema-invalid input -------------------

    def test_missing_file_is_a_schema_error(self) -> None:
        self.assert_schema_error(self.run_validator(str(self.dir / "absent.json")),
                                 "cannot read")

    def test_malformed_json_is_a_schema_error(self) -> None:
        self.assert_schema_error(self.run_validator(str(self.write("{not json"))),
                                 "not valid JSON")

    def test_wrong_schema_tag_is_rejected(self) -> None:
        bad = report()
        bad["schema"] = "proxlead-lint-v1"
        self.assert_schema_error(self.run_validator(str(self.write(bad))),
                                 "proxlead-check-v1")

    def test_execution_count_mismatch_is_rejected(self) -> None:
        bad = report()
        bad["scenarios"][0]["executions"] = 7
        self.assert_schema_error(self.run_validator(str(self.write(bad))),
                                 "dfs_executions + random_executions")

    def test_invariance_flag_must_match_outcomes(self) -> None:
        bad = report()
        bad["scenarios"][0]["outcomes"] = ["max-rounds#1", "wire-fault@r1n0#2"]
        self.assert_schema_error(self.run_validator(str(self.write(bad))),
                                 "schedule_invariant")

    def test_pass_flag_must_match_findings(self) -> None:
        bad = report()
        bad["scenarios"][0]["findings"] = [{"kind": "deadlock", "detail": "stuck at barrier"}]
        self.assert_schema_error(self.run_validator(str(self.write(bad))), "pass")

    def test_unknown_finding_kind_is_rejected(self) -> None:
        bad = report([scenario(ok=False)])
        bad["scenarios"][0]["findings"][0]["kind"] = "vibes"
        self.assert_schema_error(self.run_validator(str(self.write(bad))), "kind")

    def test_unknown_flag_is_a_usage_error(self) -> None:
        self.assert_schema_error(self.run_validator(str(self.write(report())), "--verbose"),
                                 "unknown flag")


if __name__ == "__main__":
    unittest.main(verbosity=2)
