#!/usr/bin/env python3
"""Compare a fresh BenchReport JSON against a committed baseline.

Part of the CI perf-regression gate: the perf job reruns the perf_hotpath
and wire_bytes harnesses in PERF_SMOKE mode and calls this script against
the committed ``BENCH_<name>.json`` baselines. A benchmark whose p50
regresses by more than ``--tolerance`` (default ±30%) fails the job.

Stdlib-only by design (the repo builds offline; CI runners only need a
stock python3).

Modes
-----
- baseline present: compare every (set title, result name) pair found in
  BOTH files on the ``p50_ns`` statistic; exit 1 on any regression beyond
  tolerance. Rows present on only one side are listed but never fail the
  gate (benches evolve).
- baseline missing: bootstrap mode — print how to seed the baseline from
  the uploaded artifact and exit 0. The first CI run on a runner with a
  Rust toolchain therefore *creates* the gate rather than failing it.
- ``--validate REPORT``: only check that REPORT parses as a non-empty
  BenchReport and exit. Used by scripts/bench_baseline.sh before a fresh
  report may overwrite a committed baseline.

A malformed, empty, or row-less report on either side is always a
one-line ``error:`` exit — never a traceback (covered by
scripts/test_perf_compare.py, run in CI without a Rust toolchain).
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path


REFRESH_HINT = "refresh it via scripts/bench_baseline.sh"


def load_rows(path: Path) -> tuple[dict, dict[tuple[str, str], float]]:
    """Parse one BenchReport JSON; every failure mode is a one-line
    sys.exit (the CI log must say *what* is wrong with *which* file, never
    show a traceback)."""
    try:
        text = path.read_text()
    except OSError as e:
        sys.exit(f"error: cannot read {path}: {e.strerror or e}")
    if not text.strip():
        sys.exit(f"error: {path} is empty — {REFRESH_HINT}")
    try:
        doc = json.loads(text)
    except json.JSONDecodeError as e:
        sys.exit(f"error: {path} is not valid JSON "
                 f"(line {e.lineno}, col {e.colno}: {e.msg}) — {REFRESH_HINT}")
    if not isinstance(doc, dict):
        sys.exit(f"error: {path} holds a JSON {type(doc).__name__}, "
                 f"expected a BenchReport object — {REFRESH_HINT}")
    if doc.get("schema") != "proxlead-perf-v1":
        sys.exit(f"error: {path} has schema {doc.get('schema')!r}, "
                 "expected 'proxlead-perf-v1'")
    rows: dict[tuple[str, str], float] = {}
    for s in doc.get("sets", []):
        if not isinstance(s, dict):
            continue
        title = s.get("title", "")
        for r in s.get("results", []):
            if not isinstance(r, dict):
                continue
            p50 = r.get("p50_ns")
            if isinstance(p50, (int, float)) and p50 > 0:
                rows[(title, r.get("name", ""))] = float(p50)
    if not rows:
        sys.exit(f"error: {path} contains no benchmark rows "
                 f"(schema ok, measurements missing) — {REFRESH_HINT}")
    return doc, rows


def fmt_ns(ns: float) -> str:
    for bound, unit, div in ((1e3, "ns", 1.0), (1e6, "us", 1e3), (1e9, "ms", 1e6)):
        if ns < bound:
            return f"{ns / div:.2f} {unit}"
    return f"{ns / 1e9:.3f} s"


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", type=Path,
                    help="committed BENCH_<name>.json baseline")
    ap.add_argument("--current", type=Path,
                    help="fresh bench_out/<name>.json from this run")
    ap.add_argument("--tolerance", type=float, default=0.30,
                    help="allowed fractional p50 regression (default 0.30)")
    ap.add_argument("--validate", type=Path, metavar="REPORT",
                    help="only check that REPORT parses as a non-empty "
                         "BenchReport, then exit (bench_baseline.sh runs "
                         "this before overwriting a committed baseline)")
    args = ap.parse_args()

    if args.validate is not None:
        if not args.validate.exists():
            sys.exit(f"error: {args.validate} not found")
        _, rows = load_rows(args.validate)
        print(f"ok: {args.validate} is a valid BenchReport "
              f"({len(rows)} benchmark rows)")
        return 0
    if args.baseline is None or args.current is None:
        ap.error("--baseline and --current are required (or use --validate)")

    if not args.current.exists():
        sys.exit(f"error: current report {args.current} not found — "
                 "did the bench run fail?")

    if not args.baseline.exists():
        print(f"perf_compare: no baseline at {args.baseline} — bootstrap mode.")
        print("  To arm the regression gate, commit this run's report as the "
              "baseline:")
        print(f"    cp {args.current} {args.baseline} && git add {args.baseline}")
        print("  (the perf job uploads it as an artifact named "
              "perf-regression-json).")
        return 0

    base_doc, base = load_rows(args.baseline)
    cur_doc, cur = load_rows(args.current)

    if bool(base_doc.get("smoke")) != bool(cur_doc.get("smoke")):
        print(f"warning: smoke flags differ (baseline={base_doc.get('smoke')}, "
              f"current={cur_doc.get('smoke')}); timings are not comparable "
              "across modes — treating as bootstrap, not failing.")
        return 0

    shared = sorted(set(base) & set(cur))
    only_base = sorted(set(base) - set(cur))
    only_cur = sorted(set(cur) - set(base))
    if not shared:
        print("warning: baseline and current share no benchmark rows; "
              "nothing to compare (did the harness get renamed wholesale?).")
        return 0

    regressions = []
    print(f"perf_compare: {len(shared)} shared rows, tolerance ±"
          f"{args.tolerance:.0%} on p50")
    for key in shared:
        b, c = base[key], cur[key]
        ratio = c / b
        marker = " "
        if ratio > 1.0 + args.tolerance:
            marker = "R"  # regression
            regressions.append((key, b, c, ratio))
        elif ratio < 1.0 - args.tolerance:
            marker = "+"  # improvement beyond tolerance (informational)
        print(f"  [{marker}] {key[0]} / {key[1]}: "
              f"{fmt_ns(b)} -> {fmt_ns(c)}  (x{ratio:.2f})")
    for key in only_base:
        print(f"  [-] {key[0]} / {key[1]}: only in baseline (row removed?)")
    for key in only_cur:
        print(f"  [n] {key[0]} / {key[1]}: new row (no baseline yet)")
    if only_base or only_cur:
        print(f"note: skipped {len(only_base) + len(only_cur)} one-sided "
              "row(s) — [-]/[n] rows are informational and never gate "
              "(benches evolve; refresh via scripts/bench_baseline.sh to "
              "fold new rows into the baseline).")

    if regressions:
        print(f"\nFAIL: {len(regressions)} benchmark(s) regressed beyond "
              f"{args.tolerance:.0%}:")
        for (title, name), b, c, ratio in regressions:
            print(f"  {title} / {name}: {fmt_ns(b)} -> {fmt_ns(c)} (x{ratio:.2f})")
        print("If the slowdown is intentional, refresh the baseline via "
              "scripts/bench_baseline.sh and commit the new BENCH_*.json.")
        return 1
    print("OK: no regression beyond tolerance.")
    return 0


if __name__ == "__main__":
    sys.exit(main())
