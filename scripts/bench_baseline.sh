#!/usr/bin/env sh
# Refresh the committed perf-regression baselines (BENCH_*.json).
#
# Runs the gated harnesses in the same PERF_SMOKE configuration the CI
# perf-regression job uses (smoke timings are only comparable to smoke
# timings) and copies their reports to the repo root. Commit the updated
# BENCH_*.json files together with the change that moved the numbers.
#
# Usage: scripts/bench_baseline.sh [--full]
#   --full   run without PERF_SMOKE (local deep measurement; NOT what the
#            CI gate compares against — don't commit these as baselines)

set -eu

cd "$(dirname "$0")/.."

SMOKE=1
if [ "${1:-}" = "--full" ]; then
    SMOKE=""
fi

for bench in perf_hotpath wire_bytes scaling_n; do
    echo "==> cargo bench --bench $bench ${SMOKE:+(PERF_SMOKE=1)}"
    PERF_SMOKE="$SMOKE" cargo bench --bench "$bench"
done

if [ -n "$SMOKE" ]; then
    # refuse to arm the CI gate with a malformed or empty report: each
    # fresh report must parse as a non-empty BenchReport before it may
    # overwrite a committed baseline (one-line error + nonzero exit here
    # thanks to set -e)
    for name in perf_hotpath wire_bytes scaling_n; do
        python3 scripts/perf_compare.py --validate "rust/bench_out/$name.json"
    done
    cp rust/bench_out/perf_hotpath.json BENCH_perf_hotpath.json
    cp rust/bench_out/wire_bytes.json BENCH_wire_bytes.json
    cp rust/bench_out/scaling_n.json BENCH_scaling_n.json
    echo "wrote BENCH_perf_hotpath.json, BENCH_wire_bytes.json, BENCH_scaling_n.json"
    echo "commit them to arm/refresh the CI perf-regression gate"
else
    echo "full-mode reports left in rust/bench_out/ (not copied to BENCH_*)"
fi
