#!/usr/bin/env python3
"""Cross-language oracle for the chunked bit-packing rework.

The container this repo grows in has no Rust toolchain, so the rework of
``compress::bits`` (u64-accumulator writer / whole-byte reader replacing
the historical bit-at-a-time loops) is verified here by executing BOTH
algorithms in Python and asserting byte/bit identity:

1. writer: the accumulator flush (exact port of ``BitWriter::write_bits``
   + ``finish``) against the historical per-bit MSB-first writer, over
   randomized field sequences;
2. reader: the head/body/tail whole-byte read (exact port of
   ``BitReader::try_read_bits``) against a per-bit reference reader,
   including exhaustion behaviour at every truncation point;
3. quant stream: the ∞-norm block layout (f32 norm + sign/magnitude
   fields) written by both writers and decoded by both readers, round-
   tripping sign/magnitude codes exactly.

Mirrors the Rust unit tests (`chunked_writer_matches_bit_at_a_time_
reference`, `reader_refuses_overrun`) so the same property is pinned on
both sides of the language gap. Stdlib-only; exit 0 = all checks pass.
"""

import random
import struct
import sys

MAX_FIELD_BITS = 56  # keep 7 carried bits + field inside 64 bits


# ---------------------------------------------------------------- writers
def reference_write(fields):
    """Historical writer: one bit at a time, MSB-first."""
    out = bytearray()
    nbits = 0
    for value, width in fields:
        for i in reversed(range(width)):
            if nbits // 8 == len(out):
                out.append(0)
            if (value >> i) & 1:
                out[nbits // 8] |= 1 << (7 - nbits % 8)
            nbits += 1
    return bytes(out)


def chunked_write(fields):
    """Port of the new BitWriter: u64 accumulator, whole-byte flush."""
    out = bytearray()
    acc = 0
    fill = 0
    for value, width in fields:
        assert width <= MAX_FIELD_BITS and value < (1 << width)
        acc = ((acc << width) | value) & ((1 << 64) - 1)  # u64 wrap
        fill += width
        while fill >= 8:
            fill -= 8
            out.append((acc >> fill) & 0xFF)  # `as u8` masks stale bits
    if fill > 0:  # finish(): zero-pad the low positions
        out.append((acc << (8 - fill)) & 0xFF)
    return bytes(out)


# ---------------------------------------------------------------- readers
def reference_read(data, widths):
    """Per-bit MSB-first reader; None once the stream is exhausted."""
    pos = 0
    vals = []
    for w in widths:
        if pos + w > len(data) * 8:
            vals.append(None)
            continue
        v = 0
        for _ in range(w):
            v = (v << 1) | ((data[pos // 8] >> (7 - pos % 8)) & 1)
            pos += 1
        vals.append(v)
    return vals


def chunked_read(data, widths):
    """Port of the new BitReader.try_read_bits: head/body/tail bytes."""
    pos = 0
    vals = []
    for w in widths:
        assert w <= MAX_FIELD_BITS
        if pos + w > len(data) * 8:
            vals.append(None)  # refuse the overrun, position unchanged
            continue
        v = 0
        rem = w
        p = pos
        head = (8 - p % 8) % 8
        if head > 0:
            take = min(head, rem)
            v = (data[p // 8] >> (head - take)) & ((1 << take) - 1)
            p += take
            rem -= take
        while rem >= 8:
            v = (v << 8) | data[p // 8]
            p += 8
            rem -= 8
        if rem > 0:
            v = (v << rem) | (data[p // 8] >> (8 - rem))
            p += rem
        pos = p
        vals.append(v)
    return vals


# ---------------------------------------------------------------- checks
def check_writers(trials=2000, seed=41):
    rng = random.Random(seed)
    for t in range(trials):
        fields = []
        for _ in range(1 + rng.randrange(24)):
            width = 1 + rng.randrange(MAX_FIELD_BITS)
            fields.append((rng.getrandbits(width), width))
        a = reference_write(fields)
        b = chunked_write(fields)
        assert a == b, f"writer divergence at trial {t}: {fields}"
    print(f"  writers byte-identical over {trials} randomized field lists")


def check_readers(trials=2000, seed=42):
    rng = random.Random(seed)
    for t in range(trials):
        widths = [1 + rng.randrange(MAX_FIELD_BITS)
                  for _ in range(1 + rng.randrange(24))]
        fields = [(rng.getrandbits(w), w) for w in widths]
        stream = chunked_write(fields)
        # full read, then every truncation point (overrun refusal)
        for cut in range(len(stream) + 1):
            data = stream[:cut]
            assert reference_read(data, widths) == chunked_read(data, widths), \
                f"reader divergence at trial {t}, cut {cut}"
        got = chunked_read(stream, widths)
        assert got == [v for v, _ in fields], f"roundtrip loss at trial {t}"
    print(f"  readers bit-identical over {trials} lists × every truncation")


def quant_fields(x, bits, block, dither):
    """The ∞-norm quantizer stream layout as (value, width) fields."""
    levels = float(1 << (bits - 1))
    fields = []
    codes = []
    for start in range(0, len(x), block):
        chunk = x[start:start + block]
        norm = max(abs(v) for v in chunk)
        norm32 = struct.unpack(">I", struct.pack(">f", norm))[0]
        fields.append((norm32, 32))
        if norm == 0.0:
            continue
        inv_scale = levels / norm
        for v in chunk:
            mag = min(float(int(abs(v) * inv_scale + next(dither))), levels)
            code = int(mag)
            sign = 1 if v < 0.0 else 0
            fields.append(((sign << bits) | code, bits + 1))
            codes.append((sign, code))
    return fields, codes


def check_quant_stream(trials=200, seed=43):
    rng = random.Random(seed)
    for t in range(trials):
        n = 1 + rng.randrange(300)
        bits = rng.choice([2, 4, 8])
        block = rng.choice([64, 256])
        x = [rng.gauss(0, 1) for _ in range(n)]
        dither_seq = [rng.random() for _ in range(n)]
        fields, codes = quant_fields(x, bits, block, iter(dither_seq))
        old = reference_write(fields)
        new = chunked_write(fields)
        assert old == new, f"quant stream divergence at trial {t}"
        # decode with the chunked reader: norms + sign/magnitude fields
        widths = [w for _, w in fields]
        vals = chunked_read(new, widths)
        decoded = []
        for (v, w), got in zip(fields, vals):
            assert got == v, f"quant field loss at trial {t}"
            if w != 32:
                decoded.append(((got >> bits) & 1, got & ((1 << bits) - 1)))
        assert decoded == codes, f"sign/magnitude code loss at trial {t}"
    print(f"  quant block streams byte-identical over {trials} trials")


def main():
    print("verify_bitpack: chunked accumulator vs historical per-bit codec")
    check_writers()
    check_readers()
    check_quant_stream()
    print("PASS: all bitpack equivalence checks hold")
    return 0


if __name__ == "__main__":
    sys.exit(main())
