#!/usr/bin/env python3
"""Validate a ``proxlead-check-v1`` report emitted by ``--bin check``.

Usage::

    python3 scripts/check_report.py check_report.json [--min-distinct N]

Exit status: 0 — schema-valid and every scenario passed (and met the
``--min-distinct`` floor, when given); 1 — schema-valid but at least one
scenario failed or missed the floor (details printed); 2 — unreadable
file or schema violation (one ``error:`` line, never a traceback).

CI runs this against the artifact the concurrency-check job uploads, so a
truncated or hand-edited report fails loudly instead of green-washing.
"""

from __future__ import annotations

import json
import sys
from pathlib import Path

SCHEMA = "proxlead-check-v1"
FINDING_KINDS = {"race", "deadlock", "stuck", "panic", "invariance", "coverage", "divergence"}
COUNT_KEYS = ("executions", "distinct_schedules", "dfs_executions", "random_executions",
              "max_steps")


def fail(msg: str):
    print(f"error: {msg}", file=sys.stderr)
    sys.exit(2)


def validate(report) -> list[str]:
    """Schema- and consistency-check; returns the failing scenario names."""
    if not isinstance(report, dict):
        fail("top level must be an object")
    if report.get("schema") != SCHEMA:
        fail(f"schema must be '{SCHEMA}', got {report.get('schema')!r}")
    if not isinstance(report.get("pass"), bool):
        fail("top-level 'pass' must be a bool")
    scenarios = report.get("scenarios")
    if not isinstance(scenarios, list) or not scenarios:
        fail("'scenarios' must be a non-empty array")
    failing = []
    seen = set()
    for i, s in enumerate(scenarios):
        where = f"scenarios[{i}]"
        if not isinstance(s, dict):
            fail(f"{where} must be an object")
        name = s.get("name")
        if not isinstance(name, str) or not name:
            fail(f"{where}.name must be a non-empty string")
        if name in seen:
            fail(f"duplicate scenario name '{name}'")
        seen.add(name)
        for key in ("pass", "schedule_invariant"):
            if not isinstance(s.get(key), bool):
                fail(f"{where}.{key} must be a bool")
        for key in COUNT_KEYS:
            v = s.get(key)
            if not isinstance(v, int) or isinstance(v, bool) or v < 0:
                fail(f"{where}.{key} must be a non-negative integer")
        if s["executions"] != s["dfs_executions"] + s["random_executions"]:
            fail(f"{where}: executions must equal dfs_executions + random_executions")
        if s["distinct_schedules"] > s["executions"]:
            fail(f"{where}: distinct_schedules exceeds executions")
        outcomes = s.get("outcomes")
        if not isinstance(outcomes, list) or not all(isinstance(o, str) for o in outcomes):
            fail(f"{where}.outcomes must be an array of strings")
        if s["schedule_invariant"] != (len(outcomes) <= 1):
            fail(f"{where}: schedule_invariant disagrees with the outcome count")
        findings = s.get("findings")
        if not isinstance(findings, list):
            fail(f"{where}.findings must be an array")
        for j, f in enumerate(findings):
            if not isinstance(f, dict):
                fail(f"{where}.findings[{j}] must be an object")
            if f.get("kind") not in FINDING_KINDS:
                fail(f"{where}.findings[{j}].kind must be one of {sorted(FINDING_KINDS)}")
            if not isinstance(f.get("detail"), str) or not f["detail"]:
                fail(f"{where}.findings[{j}].detail must be a non-empty string")
        if s["pass"] != (len(findings) == 0):
            fail(f"{where}: pass disagrees with findings")
        if not s["pass"]:
            failing.append(name)
    if report["pass"] != (len(failing) == 0):
        fail("top-level pass disagrees with the per-scenario passes")
    return failing


def main(argv: list[str]) -> int:
    path = None
    min_distinct = 0
    args = iter(argv[1:])
    for arg in args:
        if arg == "--min-distinct":
            raw = next(args, None)
            if raw is None or not raw.isdigit():
                fail("--min-distinct requires a non-negative integer")
            min_distinct = int(raw)
        elif arg.startswith("-"):
            fail(f"unknown flag {arg} (usage: check_report.py REPORT.json [--min-distinct N])")
        elif path is None:
            path = arg
        else:
            fail("exactly one report path expected")
    if path is None:
        fail("usage: check_report.py REPORT.json [--min-distinct N]")
    try:
        text = Path(path).read_text()
    except OSError as e:
        fail(f"cannot read {path}: {e}")
    try:
        report = json.loads(text)
    except json.JSONDecodeError as e:
        fail(f"{path} is not valid JSON: {e}")

    failing = validate(report)
    shallow = [s["name"] for s in report["scenarios"]
               if s["distinct_schedules"] < min_distinct]
    for s in report["scenarios"]:
        for f in s["findings"]:
            print(f"{s['name']}: {f['kind']}: {f['detail']}")
    for name in shallow:
        print(f"{name}: coverage: below the --min-distinct {min_distinct} floor")
    n = len(report["scenarios"])
    if failing or shallow:
        bad = sorted(set(failing) | set(shallow))
        print(f"check report: {len(bad)}/{n} scenario(s) failed: {', '.join(bad)}")
        return 1
    print(f"check report: {n} scenario(s) clean")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
