"""AOT pipeline tests: lowering emits parseable HLO text and a consistent
manifest (the rust runtime's artifact registry contract)."""

import json
import os

from compile import aot, model


def test_lowered_hlo_is_text(tmp_path):
    lowered = aot.lower_fn(model.node_grad, 8, 4, 3, 0.01)
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule")
    # tuple root so the rust side can to_tuple1()
    assert "ROOT" in text


def test_build_writes_manifest_and_artifacts(tmp_path):
    out = str(tmp_path)
    manifest = aot.build(out, [(8, 4, 3, 0.01)])
    with open(os.path.join(out, "manifest.json")) as f:
        on_disk = json.load(f)
    assert on_disk == manifest
    assert on_disk["format"] == "hlo-text"
    assert len(on_disk["artifacts"]) == 2  # grad + loss
    for art in on_disk["artifacts"]:
        p = os.path.join(out, art["file"])
        assert os.path.exists(p)
        with open(p) as f:
            assert f.read().startswith("HloModule")


def test_loss_artifact_scalar_shape(tmp_path):
    import jax.numpy as jnp
    import numpy as np
    a = jnp.zeros((8, 4), jnp.float32)
    w = jnp.zeros((4, 3), jnp.float32)
    y = jnp.asarray(np.eye(3, dtype=np.float32)[np.zeros(8, dtype=int)])
    (loss,) = model.node_loss(a, w, y, 0.01)
    assert loss.shape == (1,)
    # loss of zero weights = log C
    np.testing.assert_allclose(float(loss[0]), np.log(3.0), rtol=1e-6)
