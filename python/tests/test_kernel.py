"""Kernel-vs-reference correctness: the CORE signal that the Pallas kernel
computes the same gradient the theory (and the rust native path) assumes.
Hypothesis sweeps shapes; fixed cases pin the exact configurations the AOT
artifacts ship."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.logreg_grad import logreg_grad, row_block, vmem_footprint_bytes


def make_case(m, d, c, seed):
    rng = np.random.default_rng(seed)
    a = rng.normal(size=(m, d)).astype(np.float32)
    w = (0.3 * rng.normal(size=(d, c))).astype(np.float32)
    labels = rng.integers(0, c, size=m)
    y = np.eye(c, dtype=np.float32)[labels]
    return jnp.asarray(a), jnp.asarray(w), jnp.asarray(y)


@settings(max_examples=40, deadline=None)
@given(
    m=st.integers(min_value=1, max_value=96),
    d=st.integers(min_value=1, max_value=24),
    c=st.integers(min_value=2, max_value=8),
    lam2=st.sampled_from([0.0, 0.005, 0.1]),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_kernel_matches_ref_shapes(m, d, c, lam2, seed):
    a, w, y = make_case(m, d, c, seed)
    got = logreg_grad(a, w, y, lam2)
    want = ref.logreg_grad_ref(a, w, y, lam2)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-6)


@pytest.mark.parametrize("m,d,c,lam2", [(24, 8, 4, 0.005), (240, 64, 10, 0.005),
                                        (16, 64, 10, 0.005)])
def test_kernel_matches_ref_shipped_shapes(m, d, c, lam2):
    a, w, y = make_case(m, d, c, 7)
    got = logreg_grad(a, w, y, lam2)
    want = ref.logreg_grad_ref(a, w, y, lam2)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-6)


@pytest.mark.parametrize("block", [1, 2, 4, 8, 24])
def test_block_size_invariance(block):
    # the HBM<->VMEM schedule must not change the numerics
    a, w, y = make_case(24, 8, 4, 11)
    base = logreg_grad(a, w, y, 0.005, block_rows=24)
    tiled = logreg_grad(a, w, y, 0.005, block_rows=block)
    np.testing.assert_allclose(tiled, base, rtol=1e-6, atol=1e-7)


def test_ref_grad_is_autodiff_of_ref_loss():
    # independent check: analytic gradient == jax.grad of the loss
    a, w, y = make_case(32, 10, 5, 3)
    lam2 = 0.01
    auto = jax.grad(lambda w_: ref.logreg_loss_ref(a, w_, y, lam2))(w)
    analytic = ref.logreg_grad_ref(a, w, y, lam2)
    np.testing.assert_allclose(analytic, auto, rtol=1e-5, atol=1e-6)


def test_kernel_float64():
    # interpret mode supports f64; tolerance tightens accordingly
    with jax.enable_x64(True):
        rng = np.random.default_rng(5)
        a = jnp.asarray(rng.normal(size=(20, 6)))
        w = jnp.asarray(0.3 * rng.normal(size=(6, 3)))
        y = jnp.asarray(np.eye(3)[rng.integers(0, 3, size=20)])
        got = logreg_grad(a, w, y, 0.01)
        want = ref.logreg_grad_ref(a, w, y, 0.01)
        np.testing.assert_allclose(got, want, rtol=1e-12, atol=1e-13)


def test_extreme_logits_stable():
    # huge logits must not overflow the fused softmax
    a, w, y = make_case(16, 4, 3, 9)
    w = w * 1e4
    got = logreg_grad(a, w, y, 0.0)
    assert np.all(np.isfinite(got))
    want = ref.logreg_grad_ref(a, w, y, 0.0)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_row_block_divides():
    for m in [1, 7, 24, 96, 100, 240, 1024]:
        b = row_block(m)
        assert m % b == 0 and 1 <= b <= 128


def test_vmem_footprint_within_budget():
    # the shipped example shape must fit a TPU core's ~16 MiB VMEM easily
    assert vmem_footprint_bytes(240, 64, 10) < 16 * 2**20 / 8
