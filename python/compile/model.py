"""L2: the per-node compute graph of the paper's Section-5 workload,
written in JAX and calling the L1 Pallas kernel.

Two jittable entry points per (m, d, C, lam2) configuration:

- node_grad: the round hot-spot grad f_i(W) (Pallas-fused);
- node_loss: f_i(W) for metric logging (pure jnp; off the hot path).

python/compile/aot.py lowers these once to HLO text; the rust runtime
(rust/src/runtime/) loads and executes the artifacts via PJRT. Python is
never on the request path.
"""

import jax.numpy as jnp

from .kernels import ref
from .kernels.logreg_grad import logreg_grad


def node_grad(a, w, y_onehot, lam2):
    """grad f_i(W) = A^T(softmax(AW) - Y)/m + 2*lam2*W via the Pallas kernel.

    Returned as a 1-tuple so the lowered HLO has the tuple root the rust
    loader unwraps with to_tuple1() (see /opt/xla-example/load_hlo).
    """
    return (logreg_grad(a, w, y_onehot, lam2),)


def node_loss(a, w, y_onehot, lam2):
    """f_i(W) = mean CE + lam2*||W||^2, shaped (1,) for PJRT transport."""
    return (jnp.reshape(ref.logreg_loss_ref(a, w, y_onehot, lam2), (1,)),)
