"""L1 Pallas kernel: fused multinomial logistic-regression gradient.

    grad(W) = A^T (softmax(A W) - Y) / m  +  2 lambda2 * W

TPU mapping (DESIGN.md section Hardware-Adaptation): the grid walks
row-blocks of A (the HBM->VMEM schedule a GPU version would express with
threadblocks over rows). Each grid step keeps an (bm, d) tile of A, the
full (d, C) weight panel and an (bm, C) label tile in VMEM, runs two MXU
matmuls (A_b W and A_b^T delta) plus the VPU softmax, and accumulates into
the (d, C) output block, which is pinned to block (0, 0) across the whole
grid so the accumulator never leaves VMEM. The lambda2 term is fused into
the first grid step.

interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so the kernel is lowered to plain HLO ops (see
/opt/xla-example/README.md); real-TPU efficiency is estimated in
EXPERIMENTS.md from the VMEM footprint of these block shapes.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def row_block(m: int, target: int = 128) -> int:
    """Largest divisor of m that is <= target (the VMEM row-tile height)."""
    best = 1
    for b in range(1, min(m, target) + 1):
        if m % b == 0:
            best = b
    return best


def _grad_kernel(a_ref, w_ref, y_ref, o_ref, *, inv_m: float, lam2: float):
    i = pl.program_id(0)
    a = a_ref[...]
    logits = a @ w_ref[...]                       # MXU: (bm,d)x(d,C)
    z = logits - jnp.max(logits, axis=-1, keepdims=True)
    e = jnp.exp(z)                                # VPU
    p = e / jnp.sum(e, axis=-1, keepdims=True)
    delta = (p - y_ref[...]) * inv_m
    contrib = a.T @ delta                         # MXU: (d,bm)x(bm,C)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = contrib + 2.0 * lam2 * w_ref[...]

    @pl.when(i > 0)
    def _acc():
        o_ref[...] += contrib


def logreg_grad(a, w, y_onehot, lam2: float, block_rows: int | None = None):
    """Pallas-fused gradient; drop-in equal to kernels.ref.logreg_grad_ref.

    a: (m, d), w: (d, C), y_onehot: (m, C); lam2 is a trace-time constant
    (one AOT artifact per (shape, lam2) configuration).
    """
    m, d = a.shape
    c = w.shape[1]
    bm = block_rows or row_block(m)
    assert m % bm == 0, f"block_rows {bm} must divide m {m}"
    kernel = functools.partial(_grad_kernel, inv_m=1.0 / m, lam2=float(lam2))
    return pl.pallas_call(
        kernel,
        grid=(m // bm,),
        in_specs=[
            pl.BlockSpec((bm, d), lambda i: (i, 0)),   # A row tile
            pl.BlockSpec((d, c), lambda i: (0, 0)),    # W panel (resident)
            pl.BlockSpec((bm, c), lambda i: (i, 0)),   # Y row tile
        ],
        out_specs=pl.BlockSpec((d, c), lambda i: (0, 0)),  # accumulator
        out_shape=jax.ShapeDtypeStruct((d, c), a.dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(a, w, y_onehot)


def vmem_footprint_bytes(m: int, d: int, c: int, block_rows: int | None = None,
                         bytes_per_el: int = 4) -> int:
    """Estimated VMEM residency of one grid step (EXPERIMENTS.md section
    Perf uses this to check the tiles fit the ~16 MiB/core budget)."""
    bm = block_rows or row_block(m)
    tiles = bm * d + d * c + bm * c + d * c       # A tile, W, Y tile, out
    intermediates = bm * c * 2                     # logits + probs
    return (tiles + intermediates) * bytes_per_el
