"""Pure-jnp correctness oracle for the L1 Pallas kernel.

The kernel computes the fused multinomial logistic-regression gradient

    grad(W) = A^T (softmax(A W) - Y) / m  +  2 lambda2 * W

which is the compute hot-spot of every round of Prox-LEAD on the paper's
Section-5 workload (the rust coordinator's native implementation of the
same expression lives in rust/src/problem/logreg.rs and is cross-checked
against the PJRT-executed artifact in rust/src/runtime/).
"""

import jax.numpy as jnp


def softmax_rows(logits):
    """Numerically stable row-wise softmax."""
    z = logits - jnp.max(logits, axis=-1, keepdims=True)
    e = jnp.exp(z)
    return e / jnp.sum(e, axis=-1, keepdims=True)


def logreg_grad_ref(a, w, y_onehot, lam2):
    """Reference gradient: A^T(softmax(AW) - Y)/m + 2*lam2*W.

    a: (m, d) features, w: (d, C) weights, y_onehot: (m, C) labels.
    """
    m = a.shape[0]
    delta = softmax_rows(a @ w) - y_onehot
    return a.T @ delta / m + 2.0 * lam2 * w


def logreg_loss_ref(a, w, y_onehot, lam2):
    """Reference loss: mean cross-entropy + lam2*||W||^2."""
    logits = a @ w
    mx = jnp.max(logits, axis=-1)
    lse = jnp.log(jnp.sum(jnp.exp(logits - mx[:, None]), axis=-1)) + mx
    picked = jnp.sum(logits * y_onehot, axis=-1)
    return jnp.mean(lse - picked) + lam2 * jnp.sum(w * w)
