"""AOT lowering: jax/pallas (build time) -> HLO text -> rust PJRT (run time).

Emits one artifact per (fn, m, d, C, lam2) configuration plus a
manifest.json the rust artifact registry indexes. HLO *text* is the
interchange format, NOT the serialized proto: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids that the image's xla_extension
0.5.1 rejects; HloModuleProto::from_text_file reassigns ids (see
/opt/xla-example/README.md).

Usage:
    python -m compile.aot --out-dir ../artifacts \
        [--spec m,d,c,lam2 ...]        # default: test + example shapes
"""

import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from . import model

# (m, d, C, lam2) configurations compiled by default: a small shape the
# rust runtime tests use, and the end-to-end train_mnist_like example shape
# (full-gradient path m=240 and its 16-row minibatch for stochastic runs).
DEFAULT_SPECS = [
    (24, 8, 4, 0.005),
    (240, 64, 10, 0.005),
    (16, 64, 10, 0.005),
]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_fn(fn, m, d, c, lam2):
    a = jax.ShapeDtypeStruct((m, d), jax.numpy.float32)
    w = jax.ShapeDtypeStruct((d, c), jax.numpy.float32)
    y = jax.ShapeDtypeStruct((m, c), jax.numpy.float32)
    return jax.jit(lambda a_, w_, y_: fn(a_, w_, y_, lam2)).lower(a, w, y)


def build(out_dir: str, specs) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {"format": "hlo-text", "dtype": "f32", "artifacts": []}
    for (m, d, c, lam2) in specs:
        for fn_name, fn in [("logreg_grad", model.node_grad),
                            ("logreg_loss", model.node_loss)]:
            name = f"{fn_name}_{m}x{d}x{c}_l{lam2:g}"
            path = f"{name}.hlo.txt"
            text = to_hlo_text(lower_fn(fn, m, d, c, lam2))
            with open(os.path.join(out_dir, path), "w") as f:
                f.write(text)
            manifest["artifacts"].append({
                "name": name, "file": path, "fn": fn_name,
                "m": m, "d": d, "c": c, "lam2": lam2,
            })
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    return manifest


def parse_spec(s: str):
    m, d, c, lam2 = s.split(",")
    return (int(m), int(d), int(c), float(lam2))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--spec", action="append", type=parse_spec,
                    help="m,d,c,lam2 (repeatable; default builds the test "
                         "and example shapes)")
    args = ap.parse_args()
    manifest = build(args.out_dir, args.spec or DEFAULT_SPECS)
    n = len(manifest["artifacts"])
    print(f"wrote {n} artifacts + manifest.json to {args.out_dir}")


if __name__ == "__main__":
    main()
